"""Kernel micro-benchmarks (CPU wall time of the XLA reference paths +
interpret-mode kernel correctness cost; on TPU these become the Mosaic
kernels).  Reported so kernel-level regressions are visible in CI."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.analysis import analyze_patches
from repro.data.synthetic import bragg_patches
from repro.models.layers import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> List[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    # chunked attention vs full attention (XLA paths)
    B, S, H, Hkv, D = 1, 2048, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    t_full = _time(jax.jit(lambda a, b, c: full_attention(a, b, c)), q, k, v)
    t_chunk = _time(jax.jit(
        lambda a, b, c: chunked_attention(a, b, c, chunk=256)), q, k, v)
    t_band = _time(jax.jit(
        lambda a, b, c: chunked_attention(a, b, c, window=256, chunk=256)),
        q, k, v)
    rows.append(f"kernels/attention_full_2k,{t_full * 1e6:.0f},baseline")
    rows.append(f"kernels/attention_chunked_2k,{t_chunk * 1e6:.0f},"
                f"vs_full={t_full / t_chunk:.2f}x")
    rows.append(f"kernels/attention_banded_w256_2k,{t_band * 1e6:.0f},"
                f"vs_full={t_full / t_band:.2f}x")

    # SSD chunked scan
    Bm_, L, Hs, P, G, N = 2, 2048, 8, 64, 1, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bm_, L, Hs, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm_, L, Hs)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hs,)) * 0.3)
    Bmat = jax.random.normal(ks[3], (Bm_, L, G, N)) * 0.3
    Cmat = jax.random.normal(ks[4], (Bm_, L, G, N)) * 0.3
    t_ssd = _time(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0]),
                  x, dt, A, Bmat, Cmat)
    toks = Bm_ * L
    rows.append(f"kernels/ssd_chunked_2k,{t_ssd * 1e6:.0f},"
                f"tokens_per_s={toks / t_ssd:.0f}")

    # pseudo-Voigt analysis op (the paper's A): XLA path throughput
    d = bragg_patches(key, 4096)
    patches = d["patches"][..., 0]
    t_pv = _time(jax.jit(
        lambda p: analyze_patches(p, use_kernel=False)["centers_px"]),
        patches)
    per_peak_us = t_pv / 4096 * 1e6
    # paper: conventional A = 2.44 us/peak on 1024 cores; BraggNN E = 0.35us
    rows.append(f"kernels/pseudo_voigt_per_peak,{per_peak_us:.2f},"
                f"paper_A_us=2.44")

    # BraggNN inference (the paper's E) on this host
    from repro.configs import BraggNNConfig
    from repro.models import braggnn
    cfg = BraggNNConfig()
    params = braggnn.init_params(key, cfg)
    fwd = jax.jit(lambda p, x: braggnn.forward(p, x, cfg))
    t_e = _time(fwd, params, d["patches"])
    # NOTE: on this 1-core host E is slower than A; the paper's 200x E
    # speedup comes from edge accelerators — the ratio is reported for
    # visibility, not as a claim.
    rows.append(f"kernels/braggnn_E_per_peak,{t_e / 4096 * 1e6:.3f},"
                f"paper_E_us=0.35;host_E_vs_A="
                f"{per_peak_us / (t_e / 4096 * 1e6):.3f}x")
    return rows
