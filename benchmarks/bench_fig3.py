"""Figure 3 reproduction: ALCF<->SLAC transfer throughput vs concurrency.

The paper benchmarked Globus file transfer with one 10 Gbps DTN per side and
observed single-stream throughput well below NIC capacity, rising with
concurrent files and saturating above 1 GB/s.  We reproduce the curve from
the calibrated link model and validate its Fig.-3 properties.
"""
from __future__ import annotations

from typing import List

from repro.core import build_system
from repro.core.transfer import FileRef


def run() -> List[str]:
    rows = []
    sys_ = build_system()
    nbytes = 10 * 10**9          # 10 GB test payload
    for direction in (("slac", "alcf"), ("alcf", "slac")):
        src, dst = direction
        curve = sys_.transfer.throughput_curve(src, dst, nbytes,
                                               [1, 2, 4, 8, 16, 32])
        for conc, rate in curve.items():
            rows.append(f"fig3/{src}->{dst}/conc{conc},"
                        f"{nbytes / rate * 1e6 / 1e3:.0f},"
                        f"rate_GBps={rate / 1e9:.3f}")
    # validations
    c = sys_.transfer.throughput_curve("slac", "alcf", nbytes,
                                       [1, 4, 16])
    mono = c[1] <= c[4] <= c[16]
    sat = c[16] > 1e9
    rows.append(f"fig3/properties,0,monotonic={'PASS' if mono else 'FAIL'}"
                f";saturates_gt_1GBps={'PASS' if sat else 'FAIL'}")

    # end-to-end: actually run a multi-file transfer through the service
    for i in range(16):
        sys_.store.put("slac", FileRef(f"f{i}", nbytes // 16))
    rec = sys_.transfer.submit("slac", "alcf", [f"f{i}" for i in range(16)],
                               concurrency=16)
    rows.append(f"fig3/real_transfer_16files,{rec.duration * 1e6:.0f},"
                f"rate_GBps={rec.rate / 1e9:.3f}")
    return rows
