"""Serving benchmark: dense-slot vs paged-KV vs unified vs ragged step.

Nine scenario families, all at **equal physical KV budget**:

  * ``mixed``        — the PR 1 sweep (dense slabs vs paged blocks at
                       several request-arrival rates), plus the padding-tax
                       duel: the rectangular ``(lanes, chunk_width)`` step
                       vs the ragged flat-token step (segment-tiled, and
                       the per-token attention grid as ``ragged_pertok``)
                       under the same chunked mixed load — headline metric
                       is ``padding_efficiency`` (real tokens / padded
                       slots);
  * ``long_prompt``  — long prompts, short outputs: chunked prefill
                       (``chunk_tokens`` > 1) vs the PR 1 one-token-per-step
                       engine; headline metric is mean time-to-first-token;
  * ``prefix_heavy`` — many requests sharing one long preamble (the
                       federated-analysis shape of arXiv:2304.04297):
                       prefix-cache sharing vs re-prefilling every request;
                       headline metric is aggregate decode throughput;
  * ``all_prefill``  — a burst of varied-length prompts with one output
                       token each (prefill-dominated, the regime where the
                       per-token ragged grid re-read each lane's KV once
                       per chunk token and ran ~30% behind the rectangle
                       on CPU): rect vs ragged per-token vs ragged
                       segment-tiled; headline metric is total
                       (prefill + decode) token throughput — CI gates
                       tiled >= rect here;
  * ``decode_heavy`` — short prompts, long generations (the regime
                       speculative decode exists for: greedy tails settle
                       into repetitive/structured continuations n-gram
                       prompt-lookup drafts hit): spec (draft + verify +
                       rewind) vs the one-token-per-step baseline at
                       identical knobs; headline metrics are decode
                       throughput and mean accepted tokens per
                       speculative verification — CI gates spec >=
                       nonspec and accepted_per_spec_step >= 1.0 here.
  * ``disaggregated`` — the paper's edge<->DC split on the prefix-heavy
                       fleet: prefill at the "DC", decode at the "edge",
                       KV blocks shipped through the §4.1 transfer cost
                       model, vs the same fleet on one engine.  Reports
                       the content-addressed dedup savings (CI gates
                       shipped bytes < naive bytes) and the crossover
                       link bandwidth where the split starts winning,
                       plus a turnaround-vs-bandwidth sweep.
  * ``oversubscribed`` — the tiered-KV regime: the block pool is sized
                       to roughly HALF the concurrent working set, so the
                       scheduler must continuously preempt to keep its
                       slot guarantee.  host_swap=True (preempted /
                       evicted blocks parked in the host tier and
                       swapped back on re-admission) vs host_swap=False
                       (every preemption recomputes the victim's prefill
                       from scratch), both token-identical to a
                       free-running engine with a full pool.  CI gates
                       swap >= recompute throughput and token identity.
  * ``open_loop``    — the async-frontend evaluation shape: seeded
                       exponential (Poisson-process) arrivals at three
                       offered loads — fractions of the engine's own
                       calibrated closed-loop capacity — driven through
                       :func:`repro.serving.run_open_loop` on a SimClock
                       (idle gaps simulated, per-step compute measured),
                       with SLO-aware admission on (TTFT/TPOT targets
                       scaled off the calibrated step wall, so the same
                       relative regime reproduces on any machine).
                       Headline metric is goodput vs offered load — CI
                       gates goodput_ratio >= 0.9 at the moderate load
                       point — plus the cancel-everything leak probe on
                       a fresh host_swap engine (CI gates leak_free:
                       pool, prefix cache, host tier and swap-in queue
                       all empty after cancelling every request).
  * ``weak_scaling`` — the mesh front: the SAME per-device load on one
                       engine (1 device) vs a 4-slice sharded fleet
                       (one full engine per slice, steps overlapped
                       from a thread pool); headline metric is
                       aggregate decode throughput.  Runs in its own
                       subprocess with a 4-virtual-device XLA client so
                       the other scenarios keep the 1-device client
                       their tracked rows were measured under.  CI
                       gates fleet >= single-device on its own fresh
                       multi-core run (the slices genuinely overlap
                       there; a single-core host serializes them and
                       pays the per-slice host scheduling on top, so a
                       locally-committed ratio can sit below 1.0).

All scenarios except ``decode_heavy`` pin ``spec=False`` so their tracked
rows stay comparable with earlier PRs.

``python benchmarks/bench_serving.py [--json BENCH_serving.json] [--quick]``
emits the CSV rows plus a machine-readable JSON (tokens/s, TTFT,
concurrency, speedups) so the perf trajectory is tracked across PRs; CI
uploads it as a workflow artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import numpy as np

CACHE_LEN = 64
BLOCK_SIZE = 8
DENSE_LANES = 4
PAGED_LANES = 16
N_REQUESTS = 24
PROMPT_LO, PROMPT_HI = 4, 10
MAX_NEW = 8
ARRIVAL_RATES = (1, 2, 4)        # requests submitted per engine step

# unified-step scenario knobs
CHUNK_TOKENS = 16
LONG_PROMPT = 48
LONG_REQUESTS = 8
PREFIX_LEN = 40
PREFIX_REQUESTS = 16

# rect-vs-ragged padding-tax duel: prompts long enough that prefill chunks
# coexist with decodes in most steps (the tax the flat layout removes),
# at a dense-equivalent pool so preemption churn doesn't muddy the
# layout comparison
DUEL_PROMPT_LO, DUEL_PROMPT_HI = 24, 40
DUEL_LANES = 8

# all-prefill scenario: varied-length prompt burst, one output token each;
# sized so one drain is long enough that best-of-reps beats machine noise
ALL_PREFILL_LO, ALL_PREFILL_HI = 24, 56
ALL_PREFILL_REQUESTS = 24

# decode-heavy scenario: short prompts, long generations; draft budget per
# decode lane per step
DECODE_HEAVY_PROMPT = 6
DECODE_HEAVY_NEW = 48
DECODE_HEAVY_REQUESTS = 16
DRAFT_K = 4

# disaggregated scenario: modeled DCAI-vs-edge prefill speedup and the
# link bandwidths (bytes/s) the turnaround sweep prices the shipments at
DISAGG_DC_SPEEDUP = 8.0
DISAGG_BW_SWEEP = (1e6, 1e7, 1e8, 1.25e9, 1e10)

# oversubscribed scenario: prompts long enough that a recompute-from-
# scratch preemption costs several real prefill chunks, at a pool sized
# ~half the concurrent working set (must sit BELOW n_slots x per-seq
# blocks or the slot-guarantee loop never preempts and nothing swaps)
OVERSUB_PROMPT_LO, OVERSUB_PROMPT_HI = 24, 40
OVERSUB_REQUESTS = 16

# open-loop scenario: seeded exponential inter-arrivals at these offered
# loads (x the calibrated closed-loop capacity); SLO targets are set as
# multiples of the calibrated mean step wall so the same relative regime
# reproduces across machines — TTFT generous enough that the moderate
# point clears the CI goodput gate, tight enough that the overload point
# sheds its queue tail
OPEN_LOOP_REQUESTS = 24
OPEN_LOOP_LOADS = (0.25, 0.5, 2.0)
OPEN_LOOP_TTFT_STEPS = 12        # ttft_target = this x mean step wall
OPEN_LOOP_TPOT_STEPS = 6         # tpot_target = this x mean step wall

# weak-scaling scenario: requests PER DEVICE (the fleet run submits
# n_devices x this, round-robin landing the identical list on each
# slice); prompts long enough that per-step device compute dominates the
# per-slice host scheduling the fleet pays serially on few-core hosts
WEAK_SCALE_REQUESTS = 8
WEAK_SCALE_PROMPT_LO, WEAK_SCALE_PROMPT_HI = 24, 44
WEAK_SCALE_NEW = 16


def _requests(vocab: int):
    rng = np.random.default_rng(0)
    return [(rng.integers(0, vocab, int(rng.integers(PROMPT_LO, PROMPT_HI)))
             .astype(np.int32), MAX_NEW) for _ in range(N_REQUESTS)]


def _drive(engine, reqs, rate: int):
    """Submit ``rate`` requests per step until all are in, then drain."""
    pending = list(reqs)
    peak_active = 0
    util_sum, util_n = 0.0, 0
    t0 = time.perf_counter()
    guard = 0
    while pending or _has_work(engine):
        for _ in range(min(rate, len(pending))):
            p, m = pending.pop(0)
            engine.submit(p, m)
        engine.step()
        s = engine.stats()
        peak_active = max(peak_active, int(s["active"]))
        util_sum += float(s["block_utilization"])
        util_n += 1
        guard += 1
        assert guard < 10_000, "serving benchmark did not drain"
    dt = time.perf_counter() - t0
    s = engine.stats()
    return {
        "tok_s": engine.tokens_decoded / dt,
        "peak_active": peak_active,
        "mean_util": util_sum / max(util_n, 1),
        "steps": engine.steps,
        "preemptions": s["preemptions"],
        "padding_efficiency": float(s.get("padding_efficiency", 1.0)),
    }


def _has_work(engine) -> bool:
    if hasattr(engine, "has_work"):
        return engine.has_work()
    return bool(engine.queue or any(a is not None for a in engine.active))


def _warm(engine, prompt_len: int, vocab: int) -> None:
    """Warm THIS instance's jit (each engine jits its own step lambda)
    across every pow2 chunk width the timed run can hit, then zero the
    counters (including the prefix-cache stats the warm-up polluted)."""
    rng = np.random.default_rng(99)
    widths = {1}
    w = 1
    while w < getattr(engine, "chunk_tokens", 1):
        w *= 2
        widths.add(w)
    for w in sorted(widths | {min(prompt_len, max(widths))}):
        engine.submit(rng.integers(0, vocab, w).astype(np.int32), 2)
        engine.run_until_drained()
    if getattr(engine, "ragged", False):
        # the ragged step compiles per pow2 *total-token* bucket: trace
        # every bucket up to the budget by submitting simultaneous prompts
        # whose admission chunks sum to exactly the bucket
        budget = engine.scheduler._budget()
        b = 2
        while b <= budget:
            k = max(1, -(-b // engine.chunk_tokens))
            if k <= engine.n_slots:
                size = b // k
                for i in range(k):
                    engine.submit(rng.integers(0, vocab,
                                               size + (b - size * k if
                                                       i == 0 else 0))
                                  .astype(np.int32), 2)
                engine.run_until_drained()
            b *= 2
    if getattr(engine, "kv", None) is not None \
            and engine.kv.enable_prefix_cache:
        # warm the copy-on-write path too (a full-match admission forks the
        # shared tail block, compiling the engine's _cow copy jit)
        same = rng.integers(0, vocab, 2 * engine.block_size).astype(np.int32)
        for _ in range(2):
            engine.submit(same, 2)
            engine.run_until_drained()
    _reset_counters(engine)


def _reset_counters(engine) -> None:
    """Zero the token/step/padding counters (and the prefix-cache stats a
    warm-up polluted) so a timed drain starts from a clean slate."""
    engine.tokens_decoded = 0
    if hasattr(engine, "tokens_prefilled"):
        engine.tokens_prefilled = 0
    engine.steps = 0
    engine.scheduled_tokens = 0
    engine.padded_tokens = 0
    for attr in ("tokens_drafted", "draft_tokens_accepted",
                 "spec_verifications", "spec_tokens_emitted"):
        if hasattr(engine, attr):
            setattr(engine, attr, 0)
    for attr in ("host_swap_outs", "host_swap_ins", "host_swap_drops"):
        if hasattr(engine, attr):
            setattr(engine, attr, 0)
    sched = getattr(engine, "scheduler", None)
    if sched is not None and hasattr(sched, "total_swap_outs"):
        sched.total_swap_outs = 0
    if getattr(engine, "kv", None) is not None:
        engine.kv.prefix_hits = 0
        engine.kv.prefix_tokens_reused = 0
        engine.kv.cow_copies = 0
        engine.kv.evictions = 0
        engine.kv.rewinds = 0
        engine.kv.tokens_rewound = 0
        engine.kv.blocks_rewound = 0
        if hasattr(engine.kv, "swapped_in_tokens"):
            engine.kv.swapped_in_tokens = 0


def _drain_timed(engine, reqs) -> Dict[str, float]:
    """Submit everything, drain, report throughput + TTFT + concurrency."""
    ids = [engine.submit(p, m) for p, m in reqs]
    peak_active = 0
    done = []
    t0 = time.perf_counter()
    guard = 0
    while _has_work(engine):
        engine.step()
        peak_active = max(peak_active, int(engine.stats()["active"]))
        guard += 1
        assert guard < 20_000, "serving benchmark did not drain"
    dt = time.perf_counter() - t0
    done = engine.run_until_drained()
    assert len(done) == len(ids)
    ttft = [r.t_first_token - r.t_submit for r in done]
    s = engine.stats()
    return {
        "tok_s": engine.tokens_decoded / dt,
        # prefill-dominated scenarios: total tokens pushed through the
        # model per second (decode-only throughput is meaningless there)
        "total_tok_s": (engine.tokens_decoded
                        + getattr(engine, "tokens_prefilled", 0)) / dt,
        "ttft_mean_s": float(np.mean(ttft)),
        "ttft_p90_s": float(np.quantile(ttft, 0.9)),
        "peak_active": peak_active,
        "steps": engine.steps,
        "preemptions": int(s["preemptions"]),
        "prefix_tokens_reused": int(s.get("prefix_tokens_reused", 0)),
        "cow_copies": int(s.get("cow_copies", 0)),
        "padding_efficiency": float(s.get("padding_efficiency", 1.0)),
        "wall_s": dt,
    }


def _engines(api, params, quick: bool):
    """(name, ctor) triples: the PR 1 step shape, the PR 2 rectangular
    unified step, and the ragged flat-token step, at the same lanes /
    cache_len / block pool."""
    from repro.serving import PagedDecodeEngine
    lanes = 4 if quick else 8
    pool = lanes * (CACHE_LEN // BLOCK_SIZE) + 1

    def make(chunk, prefix, ragged):
        return PagedDecodeEngine(api, params, n_slots=lanes,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE, num_blocks=pool,
                                 chunk_tokens=chunk, prefix_cache=prefix,
                                 ragged=ragged, spec=False)

    return [("pr1", lambda: make(1, False, False)),
            ("unified", lambda: make(CHUNK_TOKENS, True, False)),
            ("ragged", lambda: make(CHUNK_TOKENS, True, True))]


def _scenario_all_prefill(api, params, vocab: int, quick: bool):
    """The regime the segment-tiled grid exists for: a burst of
    varied-length prompts, one output token each.  rect = the rectangular
    (lanes, chunk) baseline; ragged_pertok = flat stream with the
    per-token attention grid (re-reads a lane's KV per chunk token);
    ragged = flat stream with the segment-tiled grid (KV once per
    q-tile)."""
    from repro.serving import PagedDecodeEngine
    rng = np.random.default_rng(3)
    n = 16 if quick else ALL_PREFILL_REQUESTS
    reqs = [(rng.integers(0, vocab,
                          int(rng.integers(ALL_PREFILL_LO, ALL_PREFILL_HI)))
             .astype(np.int32), 1) for _ in range(n)]
    # full lane count even in quick mode: the rect-vs-tiled duel needs the
    # varied-residue padding the rectangle pays at real lane counts
    lanes = 8
    pool = lanes * (CACHE_LEN // BLOCK_SIZE) + 1

    def make(kind):
        return PagedDecodeEngine(api, params, n_slots=lanes,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE, num_blocks=pool,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=False,
                                 ragged=(kind != "rect"),
                                 tiled=(kind == "ragged"), spec=False)

    # a single drain is tens of ms on the smoke model — noise-dominated —
    # so every engine runs the identical burst several times and reports
    # its best drain (steady-state throughput, first-touch jitter shed)
    reps = 4 if quick else 6
    out = {}
    for kind in ("rect", "ragged_pertok", "ragged"):
        eng = make(kind)
        _warm(eng, ALL_PREFILL_HI, vocab)
        best = None
        for _ in range(reps):
            _reset_counters(eng)
            r = _drain_timed(eng, reqs)
            if best is None or r["total_tok_s"] > best["total_tok_s"]:
                best = r
        best["reps"] = reps
        out[kind] = best
    return out


def _scenario_decode_heavy(api, params, vocab: int, quick: bool):
    """The regime speculative decode exists for: short prompts, long
    generations, most engine steps pure decode.  The smoke model's greedy
    tails settle into repetitive continuations (as real structured output
    does), so n-gram prompt-lookup drafts land and each verification
    emits several tokens for one model step.  spec vs nonspec at
    identical knobs, best-of-N repeat drains (single smoke-scale drains
    are noise-dominated)."""
    from repro.serving import PagedDecodeEngine
    rng = np.random.default_rng(4)
    n = 8 if quick else DECODE_HEAVY_REQUESTS
    reqs = [(rng.integers(0, vocab, DECODE_HEAVY_PROMPT).astype(np.int32),
             DECODE_HEAVY_NEW) for _ in range(n)]
    lanes = 4 if quick else 8
    pool = lanes * (CACHE_LEN // BLOCK_SIZE) + 1

    def make(spec):
        return PagedDecodeEngine(api, params, n_slots=lanes,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE, num_blocks=pool,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=False, spec=spec,
                                 draft_k=DRAFT_K)

    reps = 4 if quick else 6
    out = {}
    for name, spec in (("nonspec", False), ("spec", True)):
        eng = make(spec)
        _warm(eng, DECODE_HEAVY_PROMPT, vocab)
        best = None
        for _ in range(reps):
            _reset_counters(eng)
            r = _drain_timed(eng, reqs)
            s = eng.stats()
            r["accepted_per_spec_step"] = float(s["accepted_per_spec_step"])
            r["draft_acceptance_rate"] = float(s["draft_acceptance_rate"])
            r["tokens_drafted"] = int(s["tokens_drafted"])
            r["kv_rewinds"] = int(s["kv_rewinds"])
            if best is None or r["tok_s"] > best["tok_s"]:
                best = r
        best["reps"] = reps
        out[name] = best
    return out


def _scenario_disaggregated(api, params, vocab: int, quick: bool):
    """The paper's split on the prefix-heavy fleet: one-engine serving vs
    DC-prefill -> KV shipment -> edge-decode.  Both sides run identical
    engine knobs (spec pinned off so the walls compare compute, not
    speculation luck); the disaggregated run charges DC prefill as
    modeled time (wall / DISAGG_DC_SPEEDUP), the shipments through the
    §4.1 cost model, and edge decode for real.  Output tokens are
    asserted identical to the one-engine drain."""
    from repro.serving import DisaggregatedEngine, PagedDecodeEngine
    rng = np.random.default_rng(5)
    preamble = rng.integers(0, vocab, PREFIX_LEN).astype(np.int32)
    n = max(6, PREFIX_REQUESTS // (2 if quick else 1))
    reqs = [(np.concatenate([preamble,
                             rng.integers(0, vocab,
                                          int(rng.integers(4, 9)))
                             .astype(np.int32)]), MAX_NEW)
            for _ in range(n)]
    lanes = 4 if quick else 8
    pool = lanes * (CACHE_LEN // BLOCK_SIZE) + 1

    def make():
        return PagedDecodeEngine(api, params, n_slots=lanes,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE, num_blocks=pool,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=True, spec=False)

    one = make()
    _warm(one, PREFIX_LEN + 6, vocab)
    t0 = time.perf_counter()
    ids = [one.submit(p, m) for p, m in reqs]
    ref = {r.request_id: r.generated for r in one.run_until_drained()}
    one_wall = time.perf_counter() - t0

    pf, de = make(), make()
    _warm(pf, PREFIX_LEN + 6, vocab)
    _warm(de, PREFIX_LEN + 6, vocab)
    dis = DisaggregatedEngine(pf, de, dc_speedup=DISAGG_DC_SPEEDUP)
    dids = [dis.submit(p, m) for p, m in reqs]
    done = {r.request_id: r.generated for r in dis.run_until_drained()}
    assert [done[i] for i in dids] == [ref[i] for i in ids], \
        "disaggregated output diverged from one-engine serving"
    s = dis.stats()
    crossover = dis.crossover_bandwidth(one_wall)
    return {
        "requests": n,
        "token_identical": True,
        "one_engine": {"wall_s": one_wall},
        "disaggregated": {
            "prefill_wall_s": s["prefill_wall"],
            "decode_wall_s": s["decode_wall"],
            "transfer_s": s["transfer_seconds"],
            "turnaround_s": s["turnaround"],
            "dc_speedup": DISAGG_DC_SPEEDUP,
        },
        "bytes_naive": int(s["bytes_naive"]),
        "bytes_shipped": int(s["bytes_shipped"]),
        "dedup_savings": s["dedup_savings"],
        "blocks_exported": int(s["blocks_exported"]),
        "blocks_dedup_skipped": int(s["blocks_dedup_skipped"]),
        # smallest link bandwidth where the split beats one-engine serving;
        # None when the per-shipment startup+RTT floor exceeds the modeled
        # DC compute win (true at smoke-model scale: real prefill is
        # milliseconds — see the floor below and examples/crossover_analysis)
        "crossover_nic_bps": crossover,
        "turnaround_floor_s": dis.priced_turnaround(1e18)["total"],
        "turnaround_vs_bandwidth_s": {
            f"{bw:.0e}": dis.priced_turnaround(bw)["total"]
            for bw in DISAGG_BW_SWEEP},
    }


def _scenario_oversubscribed(api, params, vocab: int, quick: bool):
    """The tiered-KV regime: pool at ~half the concurrent working set,
    so the scheduler's slot guarantee must keep preempting someone.  With
    ``host_swap=False`` every victim recomputes its prefill from scratch
    on re-admission; with ``host_swap=True`` the victim's full blocks are
    parked in the host tier at preemption time and swapped back in (one
    host->device copy) instead.  Both engines — and the free-running
    full-pool reference — must emit token-identical outputs; the tracked
    figure is the swap-vs-recompute throughput ratio (CI floor 1.0)."""
    from repro.serving import PagedDecodeEngine
    rng = np.random.default_rng(8)
    n = 8 if quick else OVERSUB_REQUESTS
    reqs = [(rng.integers(0, vocab,
                          int(rng.integers(OVERSUB_PROMPT_LO,
                                           OVERSUB_PROMPT_HI)))
             .astype(np.int32), MAX_NEW) for _ in range(n)]
    lanes = 4 if quick else 8
    # blocks one sequence needs at its longest (prompt + generation)
    need = -(-(OVERSUB_PROMPT_HI + MAX_NEW) // BLOCK_SIZE)
    full_pool = lanes * (CACHE_LEN // BLOCK_SIZE) + 1
    tight_pool = max(need + 1, (lanes * need) // 2)

    def make(num_blocks, host_swap):
        return PagedDecodeEngine(api, params, n_slots=lanes,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE,
                                 num_blocks=num_blocks,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=True, spec=False,
                                 host_swap=host_swap)

    free = make(full_pool, False)
    _warm(free, OVERSUB_PROMPT_HI, vocab)
    ids = [free.submit(p, m) for p, m in reqs]
    ref = {r.request_id: r.generated for r in free.run_until_drained()}

    reps = 3 if quick else 5
    out = {"requests": n, "pool_blocks": tight_pool,
           "working_set_blocks": lanes * need, "reps": reps}
    for name, host_swap in (("recompute", False), ("swap", True)):
        eng = make(tight_pool, host_swap)
        _warm(eng, OVERSUB_PROMPT_HI, vocab)
        # identity drain first (untimed): thrash must not change tokens
        dids = [eng.submit(p, m) for p, m in reqs]
        got = {r.request_id: r.generated for r in eng.run_until_drained()}
        assert [got[i] for i in dids] == [ref[i] for i in ids], \
            f"oversubscribed {name} output diverged from full-pool serving"
        best = None
        for _ in range(reps):
            _reset_counters(eng)
            r = _drain_timed(eng, reqs)
            s = eng.stats()
            r["swap_outs"] = int(s.get("swap_outs", 0))
            r["swap_ins"] = int(s.get("swap_ins", 0))
            r["preempt_swap_outs"] = int(s.get("preempt_swap_outs", 0))
            r["swapped_in_tokens"] = int(s.get("swapped_in_tokens", 0))
            if best is None or r["tok_s"] > best["tok_s"]:
                best = r
        out[name] = best
    out["token_identical"] = True
    out["swap_vs_recompute"] = (out["swap"]["tok_s"]
                                / max(out["recompute"]["tok_s"], 1e-9))
    return out


def _scenario_weak_scaling(quick: bool):
    """Weak scaling of the sharded front, run in a SUBPROCESS with 4
    virtual CPU devices: every other scenario keeps this process's plain
    1-device client (a multi-device client adds per-dispatch overhead —
    measured ~20% on the host-call-heavy spec path — which would break
    row comparability with earlier PRs)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()
    cmd = [sys.executable, os.path.abspath(__file__), "--weak-scaling-only"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         check=True)
    return json.loads(out.stdout.splitlines()[-1])


def _weak_scaling_body(quick: bool):
    """Hold the PER-DEVICE load fixed and compare one engine on one
    device against a fleet of one engine per device
    (:class:`ShardedDecodeEngine` over the full host mesh, pure data
    parallelism).  The fleet submission order is arranged so round-robin
    routing lands the *identical* request list on every slice; ideal
    weak scaling is aggregate throughput = n_devices x the
    single-device line, and the CI floor is >= 1.0x (a fleet must never
    serve slower than one of its slices).  Best-of-N drains on both
    sides, same rule as every other timed scenario."""
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serving import PagedDecodeEngine, ShardedDecodeEngine
    ndev = len(jax.devices())
    assert ndev >= 4, (
        "weak_scaling needs >= 4 devices; run through bench_serving.py "
        "(which spawns this with XLA_FLAGS="
        "--xla_force_host_platform_device_count=4)")
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    vocab = cfg.vocab_size
    rng = np.random.default_rng(6)
    per = 4 if quick else WEAK_SCALE_REQUESTS
    per_dev = [(rng.integers(0, vocab,
                             int(rng.integers(WEAK_SCALE_PROMPT_LO,
                                              WEAK_SCALE_PROMPT_HI)))
                .astype(np.int32), WEAK_SCALE_NEW) for _ in range(per)]
    # all submits land before any step, so least-loaded routing (lowest-
    # index tie-break) spreads each group of ndev identical copies one
    # per slice -> every slice still sees per_dev in order
    fleet_reqs = [per_dev[k // ndev] for k in range(per * ndev)]
    lanes = 4
    kw = dict(n_slots=lanes, cache_len=CACHE_LEN, block_size=BLOCK_SIZE,
              chunk_tokens=CHUNK_TOKENS, prefix_cache=False, spec=False)

    single = PagedDecodeEngine(api, params, **kw)
    fleet = ShardedDecodeEngine(api, params, mesh=make_host_mesh(), **kw)
    _warm(single, WEAK_SCALE_PROMPT_HI, vocab)
    for e in fleet.engines:
        _warm(e, WEAK_SCALE_PROMPT_HI, vocab)

    reps = 3 if quick else 5
    best_s, best_f = None, None
    for _ in range(reps):
        _reset_counters(single)
        r = _drain_timed(single, per_dev)
        if best_s is None or r["tok_s"] > best_s["tok_s"]:
            best_s = r
        for e in fleet.engines:
            _reset_counters(e)
        r = _drain_timed(fleet, fleet_reqs)
        if best_f is None or r["tok_s"] > best_f["tok_s"]:
            best_f = r
    s = fleet.stats()
    best_f["tokens_decoded_per_slice"] = s["tokens_decoded_per_slice"]
    ratio = best_f["tok_s"] / max(best_s["tok_s"], 1e-9)
    return {
        "devices": ndev,
        "slices": fleet.n_slices,
        "per_device_requests": per,
        "reps": reps,
        "single": best_s,
        "fleet": best_f,
        # aggregate fleet decode throughput over the single-device line;
        # n_devices x is ideal, >= 1.0 is the CI floor
        "aggregate_ratio": ratio,
    }


def _scenario_long_prompt(api, params, vocab: int, quick: bool):
    rng = np.random.default_rng(1)
    n = max(4, LONG_REQUESTS // (2 if quick else 1))
    reqs = [(rng.integers(0, vocab, LONG_PROMPT).astype(np.int32), MAX_NEW)
            for _ in range(n)]
    out = {}
    for name, ctor in _engines(api, params, quick):
        eng = ctor()
        _warm(eng, LONG_PROMPT, vocab)
        out[name] = _drain_timed(eng, reqs)
    return out


def _scenario_prefix_heavy(api, params, vocab: int, quick: bool):
    rng = np.random.default_rng(2)
    preamble = rng.integers(0, vocab, PREFIX_LEN).astype(np.int32)
    n = max(6, PREFIX_REQUESTS // (2 if quick else 1))
    reqs = []
    for _ in range(n):
        tail = rng.integers(0, vocab, int(rng.integers(4, 9)))
        reqs.append((np.concatenate([preamble, tail.astype(np.int32)]),
                     MAX_NEW))
    out = {}
    for name, ctor in _engines(api, params, quick):
        eng = ctor()
        _warm(eng, PREFIX_LEN + 6, vocab)
        out[name] = _drain_timed(eng, reqs)
    return out


def _open_loop_leak_probe(api, params, vocab: int) -> Dict:
    """Cancel-everything mid-flight on a FRESH (un-warmed: the prefix
    cache must end empty) host_swap engine whose pool sits far below the
    working set, so cancels land on running, preempted, and swapped-out
    sequences alike.  Returns the post-cancel occupancy of every tier
    plus the cancellation counters; ``leak_free`` gates in CI."""
    from repro.serving import PagedDecodeEngine
    rng = np.random.default_rng(13)
    shared = rng.integers(0, vocab, BLOCK_SIZE).astype(np.int32)
    prompts = [np.concatenate(
        [shared, rng.integers(0, vocab, 6).astype(np.int32)])
        for _ in range(5)]
    need = max(-(-(len(p) + 32) // BLOCK_SIZE) for p in prompts)
    eng = PagedDecodeEngine(api, params, n_slots=3, cache_len=CACHE_LEN,
                            block_size=BLOCK_SIZE,
                            chunk_tokens=BLOCK_SIZE, prefix_cache=True,
                            host_swap=True, num_blocks=need + 3)
    for p in prompts:                 # max_new large: nothing finishes
        eng.submit(p, 32)
    for _ in range(6):                # mid-flight, preempting, swapping
        eng.step()
    for rid in range(len(prompts)):
        eng.cancel(rid)
    tiers = {
        "blocks_allocated": int(eng.kv.allocator.num_allocated),
        "prefix_cache_entries": len(eng.kv._cached),
        "host_tier_entries": len(eng._host_tier),
        "queued_swap_ins": len(eng.kv.take_swap_ins()),
    }
    s = eng.stats()
    return {
        "leak_free": (not eng.has_work()
                      and all(v == 0 for v in tiers.values())),
        **tiers,
        "cancelled": s["cancelled"],
        "released_seqs": s["released_seqs"],
        "swap_ins_dropped": s["swap_ins_dropped"],
        "host_purged": s["host_purged"],
    }


def _scenario_open_loop(api, params, vocab: int, quick: bool):
    """Open-loop serving through :func:`repro.serving.run_open_loop`:
    calibrate closed-loop capacity and mean step wall on a warmed
    engine, then replay seeded exponential arrivals at the
    ``OPEN_LOOP_LOADS`` multiples of that capacity on a SimClock with
    SLO-aware admission enabled.  Reports goodput (SLO-met completions
    over non-cancelled offered) per load point plus the
    cancel-everything leak probe."""
    from repro.core.simclock import SimClock
    from repro.serving import OpenRequest, PagedDecodeEngine, \
        run_open_loop

    rng = np.random.default_rng(9)
    n = max(8, OPEN_LOOP_REQUESTS // (2 if quick else 1))
    prompts = [rng.integers(0, vocab,
                            int(rng.integers(PROMPT_LO, PROMPT_HI)))
               .astype(np.int32) for _ in range(n)]

    def make():
        return PagedDecodeEngine(api, params, n_slots=DENSE_LANES,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=True, spec=False)

    # calibrate: a closed-loop drain of the same request list fixes the
    # capacity the load points are fractions of, and the step wall the
    # SLO targets scale off
    eng = make()
    _warm(eng, PROMPT_HI, vocab)
    for p in prompts:
        eng.submit(p, MAX_NEW)
    t0 = time.perf_counter()
    steps = 0
    while eng.has_work():
        eng.step()
        steps += 1
    wall = time.perf_counter() - t0
    eng.take_finished()
    capacity_rps = n / max(wall, 1e-9)
    step_s = wall / max(steps, 1)
    ttft_target = OPEN_LOOP_TTFT_STEPS * step_s
    tpot_target = OPEN_LOOP_TPOT_STEPS * step_s

    points = []
    for load in OPEN_LOOP_LOADS:
        e = make()
        _warm(e, PROMPT_HI, vocab)
        gaps = np.random.default_rng(11).exponential(
            1.0 / (load * capacity_rps), n)
        reqs = [OpenRequest(p, MAX_NEW, t_arrival=float(t))
                for p, t in zip(prompts, np.cumsum(gaps))]
        out = run_open_loop(e, reqs, clock=SimClock(),
                            ttft_target=ttft_target,
                            tpot_target=tpot_target)
        points.append({
            "load_x": load,
            "offered_rps": out["offered_rps"],
            "goodput_rps": out["goodput_rps"],
            "goodput_ratio": out["goodput_ratio"],
            "completed": out["completed"],
            "met_slo": out["met_slo"],
            "shed": out["shed"],
            "cancelled": out["cancelled"],
            "ttft_p50_s": out["ttft_p50"],
            "ttft_p95_s": out["ttft_p95"],
            "steps": out["steps"],
            "makespan_s": out["makespan"],
        })

    return {
        "requests": n,
        "capacity_rps": capacity_rps,
        "step_s": step_s,
        "ttft_target_s": ttft_target,
        "tpot_target_s": tpot_target,
        "points": points,
        "leak": _open_loop_leak_probe(api, params, vocab),
    }


def run(quick: bool = False, results: Dict = None) -> List[str]:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedDecodeEngine, SlotDecodeEngine

    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg.vocab_size)
    pool_blocks = DENSE_LANES * CACHE_LEN // BLOCK_SIZE + 1   # equal budget

    def make(kind):
        if kind == "slot":
            return SlotDecodeEngine(api, params, n_slots=DENSE_LANES,
                                    cache_len=CACHE_LEN)
        if kind == "paged":
            # pinned to the PR 1 step shape (one-token prefill, no prefix
            # cache) so these tracked rows stay comparable across PRs; the
            # unified step is measured by the scenarios below
            return PagedDecodeEngine(api, params, n_slots=PAGED_LANES,
                                     cache_len=CACHE_LEN,
                                     block_size=BLOCK_SIZE,
                                     num_blocks=pool_blocks,
                                     chunk_tokens=1, prefix_cache=False,
                                     ragged=False, spec=False)
        # the padding-tax duel: chunked prefill mixing with decodes, the
        # rectangular (lanes, width) layout vs the ragged flat stream
        # (per-token and segment-tiled attention grids) at identical
        # scheduler knobs
        return PagedDecodeEngine(api, params, n_slots=DUEL_LANES,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE,
                                 chunk_tokens=CHUNK_TOKENS,
                                 prefix_cache=False,
                                 ragged=(kind != "rect"),
                                 tiled=(kind == "ragged"))

    rng = np.random.default_rng(7)
    duel_reqs = [(rng.integers(0, cfg.vocab_size,
                               int(rng.integers(DUEL_PROMPT_LO,
                                                DUEL_PROMPT_HI)))
                  .astype(np.int32), MAX_NEW) for _ in range(N_REQUESTS)]

    rows = []
    mixed = {}
    pad_tokens = {"rect": [0, 0], "ragged": [0, 0]}   # [real, padded]
    for kind in ("slot", "paged", "rect", "ragged_pertok", "ragged"):
        for rate in ARRIVAL_RATES if not quick else ARRIVAL_RATES[:1]:
            eng = make(kind)
            _warm(eng, PROMPT_HI, cfg.vocab_size)
            duel = kind in pad_tokens or kind == "ragged_pertok"
            r = _drive(eng, duel_reqs if duel else reqs, rate)
            mixed[f"{kind}_rate{rate}"] = r
            if kind in pad_tokens:
                pad_tokens[kind][0] += eng.scheduled_tokens
                pad_tokens[kind][1] += eng.padded_tokens
            us = 1e6 / max(r["tok_s"], 1e-9)
            rows.append(
                f"serving/{kind}_rate{rate},{us:.0f},"
                f"tok_s={r['tok_s']:.1f};peak_active={r['peak_active']};"
                f"util={r['mean_util']:.2f};steps={r['steps']};"
                f"preempt={r['preemptions']};"
                f"pad_eff={r['padding_efficiency']:.2f}")

    long_prompt = _scenario_long_prompt(api, params, cfg.vocab_size, quick)
    prefix_heavy = _scenario_prefix_heavy(api, params, cfg.vocab_size, quick)
    all_prefill = _scenario_all_prefill(api, params, cfg.vocab_size, quick)
    decode_heavy = _scenario_decode_heavy(api, params, cfg.vocab_size, quick)
    disagg = _scenario_disaggregated(api, params, cfg.vocab_size, quick)
    oversub = _scenario_oversubscribed(api, params, cfg.vocab_size, quick)
    open_loop = _scenario_open_loop(api, params, cfg.vocab_size, quick)
    weak = _scenario_weak_scaling(quick)
    ttft_speedup = (long_prompt["pr1"]["ttft_mean_s"]
                    / max(long_prompt["unified"]["ttft_mean_s"], 1e-9))
    tput_speedup = (prefix_heavy["unified"]["tok_s"]
                    / max(prefix_heavy["pr1"]["tok_s"], 1e-9))
    spec_speedup = (decode_heavy["spec"]["tok_s"]
                    / max(decode_heavy["nonspec"]["tok_s"], 1e-9))
    # the tiled-grid duel: segment-tiled vs per-token vs rect on the
    # all-prefill burst, by total (prefill + decode) throughput
    ap_tiled_vs_rect = (all_prefill["ragged"]["total_tok_s"]
                        / max(all_prefill["rect"]["total_tok_s"], 1e-9))
    ap_tiled_vs_pertok = (
        all_prefill["ragged"]["total_tok_s"]
        / max(all_prefill["ragged_pertok"]["total_tok_s"], 1e-9))
    for scen, res in (("long_prompt", long_prompt),
                      ("prefix_heavy", prefix_heavy)):
        for name, r in res.items():
            us = 1e6 / max(r["tok_s"], 1e-9)
            rows.append(
                f"serving/{scen}_{name},{us:.0f},"
                f"tok_s={r['tok_s']:.1f};ttft_ms={r['ttft_mean_s']*1e3:.0f};"
                f"steps={r['steps']};reused={r['prefix_tokens_reused']};"
                f"cow={r['cow_copies']};"
                f"pad_eff={r['padding_efficiency']:.2f}")
    for name, r in all_prefill.items():
        us = 1e6 / max(r["total_tok_s"], 1e-9)
        rows.append(
            f"serving/all_prefill_{name},{us:.0f},"
            f"total_tok_s={r['total_tok_s']:.1f};"
            f"ttft_ms={r['ttft_mean_s']*1e3:.0f};steps={r['steps']};"
            f"pad_eff={r['padding_efficiency']:.2f}")
    for name, r in decode_heavy.items():
        us = 1e6 / max(r["tok_s"], 1e-9)
        rows.append(
            f"serving/decode_heavy_{name},{us:.0f},"
            f"tok_s={r['tok_s']:.1f};steps={r['steps']};"
            f"accepted_per_step={r['accepted_per_spec_step']:.2f};"
            f"accept_rate={r['draft_acceptance_rate']:.2f};"
            f"rewinds={r['kv_rewinds']}")
    xo = disagg["crossover_nic_bps"]
    rows.append(
        f"serving/disaggregated,0,"
        f"one_engine_wall_s={disagg['one_engine']['wall_s']:.3f};"
        f"turnaround_s={disagg['disaggregated']['turnaround_s']:.3f};"
        f"transfer_s={disagg['disaggregated']['transfer_s']:.3f};"
        f"bytes_shipped={disagg['bytes_shipped']};"
        f"bytes_naive={disagg['bytes_naive']};"
        f"dedup_savings={disagg['dedup_savings']:.2f};"
        f"crossover_nic_bps={'none' if xo is None else f'{xo:.3g}'}")
    rows.append(
        f"serving/oversubscribed,0,"
        f"swap_tok_s={oversub['swap']['tok_s']:.1f};"
        f"recompute_tok_s={oversub['recompute']['tok_s']:.1f};"
        f"swap_vs_recompute={oversub['swap_vs_recompute']:.2f}x;"
        f"pool={oversub['pool_blocks']};"
        f"working_set={oversub['working_set_blocks']};"
        f"preempt={oversub['swap']['preemptions']};"
        f"swap_outs={oversub['swap']['swap_outs']};"
        f"swap_ins={oversub['swap']['swap_ins']};"
        f"preempt_swap_outs={oversub['swap']['preempt_swap_outs']}")
    for pt in open_loop["points"]:
        rows.append(
            f"serving/open_loop_x{pt['load_x']:g},0,"
            f"offered_rps={pt['offered_rps']:.2f};"
            f"goodput_rps={pt['goodput_rps']:.2f};"
            f"goodput_ratio={pt['goodput_ratio']:.2f};"
            f"completed={pt['completed']};met={pt['met_slo']};"
            f"shed={pt['shed']};"
            f"ttft_p50_ms={(pt['ttft_p50_s'] or 0) * 1e3:.0f};"
            f"ttft_p95_ms={(pt['ttft_p95_s'] or 0) * 1e3:.0f}")
    lk = open_loop["leak"]
    rows.append(
        f"serving/open_loop_leak,0,leak_free={lk['leak_free']};"
        f"cancelled={lk['cancelled']};"
        f"released_seqs={lk['released_seqs']};"
        f"swap_ins_dropped={lk['swap_ins_dropped']};"
        f"host_purged={lk['host_purged']}")
    rows.append(
        f"serving/weak_scaling,0,"
        f"devices={weak['devices']};slices={weak['slices']};"
        f"single_tok_s={weak['single']['tok_s']:.1f};"
        f"fleet_tok_s={weak['fleet']['tok_s']:.1f};"
        f"aggregate_ratio={weak['aggregate_ratio']:.2f}x;"
        f"per_slice_tokens={weak['fleet']['tokens_decoded_per_slice']}")
    # scenario-aggregate padding efficiency (total real / total padded
    # across every arrival rate)
    pad_eff_ragged = pad_tokens["ragged"][0] / max(pad_tokens["ragged"][1], 1)
    pad_eff_rect = pad_tokens["rect"][0] / max(pad_tokens["rect"][1], 1)
    rows.append(f"serving/speedups,0,ttft_long_prompt={ttft_speedup:.2f}x;"
                f"throughput_prefix_heavy={tput_speedup:.2f}x;"
                f"all_prefill_tiled_vs_rect={ap_tiled_vs_rect:.2f}x;"
                f"all_prefill_tiled_vs_pertok={ap_tiled_vs_pertok:.2f}x;"
                f"decode_heavy_spec_vs_nonspec={spec_speedup:.2f}x;"
                f"padding_eff_mixed_ragged={pad_eff_ragged:.2f};"
                f"padding_eff_mixed_rect={pad_eff_rect:.2f}")

    if results is not None:
        results.update({
            "arch": cfg.name,
            "config": {"cache_len": CACHE_LEN, "block_size": BLOCK_SIZE,
                       "chunk_tokens": CHUNK_TOKENS, "draft_k": DRAFT_K,
                       "quick": quick},
            "scenarios": {"mixed": mixed, "long_prompt": long_prompt,
                          "prefix_heavy": prefix_heavy,
                          "all_prefill": all_prefill,
                          "decode_heavy": decode_heavy,
                          "disaggregated": disagg,
                          "oversubscribed": oversub,
                          "open_loop": open_loop,
                          "weak_scaling": weak},
            "speedups": {"ttft_long_prompt": ttft_speedup,
                         "throughput_prefix_heavy": tput_speedup,
                         "all_prefill_tiled_vs_rect": ap_tiled_vs_rect,
                         "all_prefill_tiled_vs_pertok": ap_tiled_vs_pertok,
                         "decode_heavy_spec_vs_nonspec": spec_speedup,
                         "oversubscribed_swap_vs_recompute":
                             oversub["swap_vs_recompute"],
                         "weak_scaling_aggregate": weak["aggregate_ratio"]},
            "padding_efficiency": {"mixed_ragged": pad_eff_ragged,
                                   "mixed_rect": pad_eff_rect},
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write machine-readable results (BENCH_serving.json)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI-sized)")
    ap.add_argument("--weak-scaling-only", action="store_true",
                    help="internal: run just the weak_scaling body and "
                         "print its JSON (spawned by the main run with a "
                         "4-virtual-device XLA client)")
    args = ap.parse_args()
    if args.weak_scaling_only:
        print(json.dumps(_weak_scaling_body(args.quick), sort_keys=True))
        return
    results: Dict = {}
    for row in run(quick=args.quick, results=results):
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
