"""Serving benchmark: dense-slot vs paged-KV decode at equal memory budget.

Both engines get the same physical KV budget (``DENSE_LANES * CACHE_LEN``
cached tokens per layer).  The dense engine must carve it into
``DENSE_LANES`` fixed slabs; the paged engine shares it as a block pool
across ``PAGED_LANES`` lanes, committing blocks only as sequences grow.
At several request-arrival rates we measure decode throughput (tokens/s,
compile excluded), peak admitted concurrency, and cache utilization.

Run: PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

CACHE_LEN = 64
BLOCK_SIZE = 8
DENSE_LANES = 4
PAGED_LANES = 16
N_REQUESTS = 24
PROMPT_LO, PROMPT_HI = 4, 10
MAX_NEW = 8
ARRIVAL_RATES = (1, 2, 4)        # requests submitted per engine step


def _requests(vocab: int):
    rng = np.random.default_rng(0)
    return [(rng.integers(0, vocab, int(rng.integers(PROMPT_LO, PROMPT_HI)))
             .astype(np.int32), MAX_NEW) for _ in range(N_REQUESTS)]


def _drive(engine, reqs, rate: int):
    """Submit ``rate`` requests per step until all are in, then drain."""
    pending = list(reqs)
    peak_active = 0
    util_sum, util_n = 0.0, 0
    t0 = time.perf_counter()
    guard = 0
    while pending or _has_work(engine):
        for _ in range(min(rate, len(pending))):
            p, m = pending.pop(0)
            engine.submit(p, m)
        engine.step()
        s = engine.stats()
        peak_active = max(peak_active, int(s["active"]))
        util_sum += float(s["block_utilization"])
        util_n += 1
        guard += 1
        assert guard < 10_000, "serving benchmark did not drain"
    dt = time.perf_counter() - t0
    return {
        "tok_s": engine.tokens_decoded / dt,
        "peak_active": peak_active,
        "mean_util": util_sum / max(util_n, 1),
        "steps": engine.steps,
        "preemptions": engine.stats()["preemptions"],
    }


def _has_work(engine) -> bool:
    if hasattr(engine, "scheduler"):
        return engine.scheduler.has_work()
    return bool(engine.queue or any(a is not None for a in engine.active))


def run() -> List[str]:
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedDecodeEngine, SlotDecodeEngine

    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg.vocab_size)
    pool_blocks = DENSE_LANES * CACHE_LEN // BLOCK_SIZE + 1   # equal budget

    def make(kind):
        if kind == "slot":
            return SlotDecodeEngine(api, params, n_slots=DENSE_LANES,
                                    cache_len=CACHE_LEN)
        return PagedDecodeEngine(api, params, n_slots=PAGED_LANES,
                                 cache_len=CACHE_LEN,
                                 block_size=BLOCK_SIZE,
                                 num_blocks=pool_blocks)

    rows = []
    for kind in ("slot", "paged"):
        for rate in ARRIVAL_RATES:
            eng = make(kind)
            # warm THIS instance's jit outside the timed region (each engine
            # jits its own step lambda, so a throwaway engine warms nothing),
            # then zero the counters the timed drive reports
            eng.submit(reqs[0][0], 2)
            eng.run_until_drained()
            eng.tokens_decoded = 0
            eng.steps = 0
            r = _drive(eng, reqs, rate)
            us = 1e6 / max(r["tok_s"], 1e-9)
            rows.append(
                f"serving/{kind}_rate{rate},{us:.0f},"
                f"tok_s={r['tok_s']:.1f};peak_active={r['peak_active']};"
                f"util={r['mean_util']:.2f};steps={r['steps']};"
                f"preempt={r['preemptions']}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
