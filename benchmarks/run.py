"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_table1 — Table 1: end-to-end turnaround (local vs remote DCAI)
  * bench_fig3   — Figure 3: transfer throughput vs concurrency
  * bench_fig4   — Figure 4: conventional vs ML-surrogate crossover
  * bench_kernels— kernel/op micro-benchmarks (A and E ops incl.)
  * roofline     — §Roofline summary from dry-run artifacts (if present)
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig3, bench_fig4, bench_kernels,
                            bench_moe_impls, bench_serving, bench_table1)

    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_table1, bench_fig3, bench_fig4, bench_kernels,
                bench_moe_impls, bench_serving):
        try:
            for row in mod.run():
                print(row)
        except Exception as e:  # pragma: no cover
            failures += 1
            traceback.print_exc()
            print(f"{mod.__name__},0,ERROR={type(e).__name__}")

    # roofline summary (reads dry-run artifacts if the sweep has been run;
    # prefers the final shipped sweep)
    art_dir = os.path.join(os.getcwd(), "artifacts", "dryrun_final")
    if not os.path.isdir(art_dir):
        art_dir = os.path.join(os.getcwd(), "artifacts", "dryrun_paper_faithful")
    if os.path.isdir(art_dir):
        try:
            from benchmarks.roofline_report import load_all
            from repro.roofline.analysis import from_artifact
            arts = [a for a in load_all(art_dir)
                    if a["status"] == "OK" and a["mesh"] == "16x16"]
            n_dom = {}
            for a in arts:
                t = from_artifact(a)
                n_dom[t.dominant] = n_dom.get(t.dominant, 0) + 1
                print(f"roofline/{t.arch}/{t.shape},"
                      f"{t.step_time_lower_bound * 1e6:.0f},"
                      f"dominant={t.dominant};mfu_bound={t.mfu_upper_bound:.2f}")
            print(f"roofline/summary,0,combos={len(arts)};"
                  + ";".join(f"{k}={v}" for k, v in sorted(n_dom.items())))
        except Exception:
            traceback.print_exc()
            failures += 1

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
