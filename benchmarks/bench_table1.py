"""Table 1 reproduction: end-to-end (re)train turnaround, local vs remote.

For each DNN (BraggNN, CookieNetAE) x execution mode, runs the FULL
workflow (transfer -> train -> model return -> register) through the flow
engine.  Training on this container is real (reduced steps); DCAI / local-GPU
compute durations use the paper's measured constants (Table 1), clearly
tagged "modeled"; WAN costs come from the calibrated transfer model.

Validates the paper's headline claim: remote DCAI turnaround < 1/30 local.
"""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.core import build_system, dnn_trainer_flow
from repro.core.transfer import FileRef

# paper Table 1 measured training times (seconds)
PAPER_TRAIN_S = {
    ("braggnn", "local-v100"): 1102.0,
    ("braggnn", "cerebras"): 19.0,
    ("braggnn", "sambanova-1rdu"): 139.0,
    ("cookienetae", "local-v100"): 517.0,
    ("cookienetae", "cerebras"): 6.0,
    ("cookienetae", "gpu-server-8xv100"): 88.0,
}
# paper Table 1 measured transfer times (s): (data, model)
PAPER_XFER_S = {
    "braggnn": (7.0, 5.0),
    "cookienetae": (5.0, 4.0),
}
PAPER_END2END = {
    ("braggnn", "local-v100"): 1102.0,
    ("braggnn", "cerebras"): 31.0,
    ("braggnn", "sambanova-1rdu"): 151.0,
    ("cookienetae", "local-v100"): 517.0,
    ("cookienetae", "cerebras"): 15.0,
    ("cookienetae", "gpu-server-8xv100"): 97.0,
}

# dataset sizes chosen so the calibrated WAN model reproduces the paper's
# measured transfer times (~7 s at ~1 GB/s with startup costs)
DATASET_BYTES = {"braggnn": 5_000_000_000, "cookienetae": 3_200_000_000}
MODEL_BYTES = {"braggnn": 3_000_000, "cookienetae": 1_400_000}


def _train_fn_real(sys_, model_name: str, steps: int = 5):
    """Real (reduced) training so the artifact carries real weights."""

    def train():
        import jax.numpy as jnp
        from repro.optim import adam
        key = jax.random.PRNGKey(0)
        if model_name == "braggnn":
            from repro.configs import BraggNNConfig
            from repro.data.synthetic import bragg_patches
            from repro.models import braggnn as mod
            cfg = BraggNNConfig()
            params = mod.init_params(key, cfg)
            opt = adam(1e-3)
            st = opt.init(params)
            for i in range(steps):
                d = bragg_patches(jax.random.fold_in(key, i), 32)
                (_, _), g = jax.value_and_grad(
                    lambda p: mod.loss_fn(p, {"patches": d["patches"],
                                              "centers": d["centers"]},
                                          cfg), has_aux=True)(params)
                params, st = opt.update(g, st, params)
        else:
            from repro.configs import CookieNetAEConfig
            from repro.data.synthetic import cookiebox_shots
            from repro.models import cookienetae as mod
            cfg = CookieNetAEConfig()
            params = mod.init_params(key, cfg)
            opt = adam(1e-3)
            st = opt.init(params)
            for i in range(steps):
                d = cookiebox_shots(jax.random.fold_in(key, i), 8)
                (_, _), g = jax.value_and_grad(
                    lambda p: mod.loss_fn(p, {"images": d["images"],
                                              "targets": d["targets"]},
                                          cfg), has_aux=True)(params)
                params, st = opt.update(g, st, params)
        sys_.store.put("alcf", FileRef(f"{model_name}.npz",
                                       MODEL_BYTES[model_name],
                                       payload=params))
        return {"ok": True}

    return sys_.funcx.register_function(train, model_name)


def run_remote(model_name: str, device: str) -> Dict[str, float]:
    sys_ = build_system()
    tok = sys_.user_token()
    n_files = 10
    per = DATASET_BYTES[model_name] // n_files
    for i in range(n_files):
        sys_.store.put("slac", FileRef(f"{model_name}-{i}.h5", per))
    fid = _train_fn_real(sys_, model_name)
    eid = sys_.funcx.register_endpoint(device, mode="modeled")
    flow = sys_.flows.deploy(dnn_trainer_flow())
    run = sys_.flows.run(flow, {
        "src": "slac", "dc": "alcf",
        "dataset": [f"{model_name}-{i}.h5" for i in range(n_files)],
        "train_endpoint": eid, "train_function": fid,
        "train_args": [], "train_kwargs": {},
        "modeled_duration": PAPER_TRAIN_S[(model_name, device)],
        "model_artifacts": [f"{model_name}.npz"],
        "model_name": f"{model_name}.npz",
        "register_as": model_name, "version_tag": device, "metrics": {},
    }, tok)
    assert run.status == "SUCCEEDED", run.log
    steps = run.step_seconds()
    return {
        "data_transfer": steps["TransferData"],
        "train": steps["TrainModel"],
        "model_transfer": steps["TransferModel"],
        "end_to_end": run.turnaround,
    }


def run_local(model_name: str) -> Dict[str, float]:
    sys_ = build_system()
    fid = _train_fn_real(sys_, model_name)
    eid = sys_.funcx.register_endpoint("local-v100", mode="modeled")
    tr = sys_.funcx.run(eid, fid, modeled_duration=PAPER_TRAIN_S[
        (model_name, "local-v100")])
    return {"data_transfer": 0.0, "train": tr.duration,
            "model_transfer": 0.0, "end_to_end": tr.duration + tr.overhead}


def run() -> List[str]:
    rows = []
    scenarios = [
        ("braggnn", "local-v100", run_local),
        ("braggnn", "cerebras", run_remote),
        ("braggnn", "sambanova-1rdu", run_remote),
        ("cookienetae", "local-v100", run_local),
        ("cookienetae", "cerebras", run_remote),
        ("cookienetae", "gpu-server-8xv100", run_remote),
    ]
    results = {}
    for model, device, fn in scenarios:
        r = fn(model) if fn is run_local else fn(model, device)
        results[(model, device)] = r
        paper = PAPER_END2END[(model, device)]
        rows.append(
            f"table1/{model}/{device},{r['end_to_end'] * 1e6:.0f},"
            f"end_to_end={r['end_to_end']:.1f}s"
            f";data={r['data_transfer']:.1f}s;train={r['train']:.1f}s"
            f";model={r['model_transfer']:.1f}s;paper={paper:.0f}s")
    # the paper's claim: remote cerebras < local/30
    for model in ("braggnn", "cookienetae"):
        speedup = (results[(model, "local-v100")]["end_to_end"]
                   / results[(model, "cerebras")]["end_to_end"])
        ok = speedup > 30.0
        rows.append(f"table1/{model}/speedup_vs_local,"
                    f"{speedup * 1e6:.0f},x{speedup:.1f}"
                    f";claim_gt30x={'PASS' if ok else 'FAIL'}")
    return rows
