"""§Perf-1 support bench: GShard einsum dispatch vs gather dispatch.

Wall time on this host is *not* the TPU story (the dry-run FLOP/collective
terms are), but the relative FLOP weight of the one-hot dispatch is visible
even on CPU, and this bench guards against regressions in both impls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as moe_lib


def run() -> List[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    base = get_config("deepseek-moe-16b").smoke_variant()
    # scale up a bit so dispatch cost is visible: 16 experts, d 256
    cfg0 = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, n_experts=16,
                                      experts_per_token=4, d_expert=256))
    p = moe_lib.moe_params(key, cfg0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 512, cfg0.d_model),
                          jnp.float32)

    results = {}
    for impl in ("gshard", "gather"):
        cfg = dataclasses.replace(
            cfg0, moe=dataclasses.replace(cfg0.moe, impl=impl))
        fn = jax.jit(lambda p_, x_: moe_lib.apply_moe(p_, x_, cfg)[0])
        fn(p, x)  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(p, x)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 5
        results[impl] = dt
        rows.append(f"moe/{impl}_dispatch,{dt * 1e6:.0f},"
                    f"tokens_per_s={4 * 512 / dt:.0f}")
    rows.append(f"moe/gather_speedup,0,"
                f"x{results['gshard'] / results['gather']:.2f}")
    return rows
