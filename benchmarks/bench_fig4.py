"""Figure 4 reproduction: conventional vs ML-surrogate cost vs dataset size.

Sweeps N (number of Bragg peaks) through Eq. (1) and Eq. (3) with the
paper's §4.2 constants and reports the crossover — the dataset size above
which shipping a subset to the DCAI, training BraggNN, and estimating the
rest at the edge beats conventional analysis at the data center.
"""
from __future__ import annotations

from typing import List

from repro.core import build_system


def run() -> List[str]:
    rows = []
    cm = build_system().costmodel
    for n in (10**4, 10**5, 10**6, 10**7, 10**8, 10**9):
        conv = cm.f_conventional_dc(n).total
        ml = cm.f_ml(n, p=0.1).total
        winner = "ml" if ml < conv else "conventional"
        rows.append(f"fig4/N{n:.0e},{conv * 1e6 / max(n, 1):.2f},"
                    f"conv={conv:.1f}s;ml={ml:.1f}s;winner={winner}")
    n_star = cm.crossover(p=0.1)
    rows.append(f"fig4/crossover,0,N_star={n_star}"
                f";small_N_prefers_conventional="
                f"{'PASS' if cm.advise(10**4) != 'ml_surrogate' else 'FAIL'}"
                f";large_N_prefers_ml="
                f"{'PASS' if cm.advise(10**9) == 'ml_surrogate' else 'FAIL'}")
    # sensitivity to labeled fraction p (beyond-paper analysis)
    for p in (0.02, 0.05, 0.1, 0.2):
        rows.append(f"fig4/crossover_p{p},0,N_star={cm.crossover(p=p)}")
    return rows
