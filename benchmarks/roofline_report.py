"""Generate the §Dry-run + §Roofline tables from dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun_final]
Prints markdown; also writes artifacts/roofline_table.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.roofline.analysis import from_artifact

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_all(d: str) -> List[Dict]:
    arts = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            arts.append(json.load(f))
    return arts


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_rows(arts: List[Dict], mesh: str = "16x16") -> List[str]:
    rows = []
    key = lambda a: (a["arch"], SHAPE_ORDER.index(a["shape"]))
    for a in sorted([x for x in arts if x["mesh"] == mesh], key=key):
        if a["status"] == "SKIPPED":
            rows.append(f"| {a['arch']} | {a['shape']} | SKIP | "
                        f"{a['skip_reason'][:60]}… ||||||")
            continue
        t = from_artifact(a)
        rows.append(
            f"| {t.arch} | {t.shape} | {fmt_s(t.compute_term)} | "
            f"{fmt_s(t.memory_term)} | {fmt_s(t.collective_term)} | "
            f"**{t.dominant}** | {t.model_flops:.2e} | "
            f"{t.useful_flops_ratio:.2f} | {t.mfu_upper_bound:.2f} |")
    return rows


def dryrun_rows(arts: List[Dict]) -> List[str]:
    rows = []
    key = lambda a: (a["arch"], SHAPE_ORDER.index(a["shape"]), a["mesh"])
    for a in sorted(arts, key=key):
        if a["status"] == "SKIPPED":
            rows.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                        f"SKIP | {a['skip_reason'][:50]}… ||||")
            continue
        mem = a.get("memory_analysis", {})
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)
              - mem.get("alias_size_in_bytes", 0)) / 1e9
        coll = a.get("collectives", {})
        sched = ",".join(f"{k.split('-')[-1][:4]}x{int(v['count'])}"
                         for k, v in sorted(coll.items()))
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | OK | "
            f"{gb:.2f} | {a['collective_bytes_total']:.2e} | "
            f"{sched} | {a['compile_s']:.0f}s |")
    return rows


def perf_variant_rows(d: str) -> List[str]:
    """§Perf tagged-variant artifacts (artifacts/perf/*.json)."""
    rows = []
    for a in load_all(d):
        if a.get("status") != "OK":
            continue
        t = from_artifact(a)
        tag = a.get("tag", "")
        rows.append(
            f"| {t.arch} | {t.shape} | {tag} | {a.get('moe_impl')} | "
            f"{a.get('sharding_policy')} | {fmt_s(t.compute_term)} | "
            f"{fmt_s(t.collective_term)} | {t.dominant} |")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun_final")
    ap.add_argument("--perf-dir", default="artifacts/perf")
    ap.add_argument("--out", default="artifacts/roofline_table.md")
    args = ap.parse_args()
    arts = load_all(args.dir)
    n_ok = sum(1 for a in arts if a["status"] == "OK")
    n_skip = sum(1 for a in arts if a["status"] == "SKIPPED")

    lines = []
    lines.append(f"## Dry-run matrix ({n_ok} compiled, {n_skip} skipped)\n")
    lines.append("| arch | shape | mesh | status | bytes/dev GB | "
                 "coll B/dev | collective schedule | compile |")
    lines.append("|---|---|---|---|---|---|---|---|")
    lines.extend(dryrun_rows(arts))
    lines.append("")
    lines.append("## Roofline (single-pod 16x16, 256 chips)\n")
    lines.append("| arch | shape | t_compute | t_memory | t_collective | "
                 "dominant | MODEL_FLOPS | useful ratio | MFU bound |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    lines.extend(roofline_rows(arts, "16x16"))
    import os as _os
    if _os.path.isdir(args.perf_dir):
        lines.append("")
        lines.append("## §Perf tagged variants (see EXPERIMENTS.md §Perf)\n")
        lines.append("| arch | shape | tag | moe_impl | policy | "
                     "t_compute | t_collective | dominant |")
        lines.append("|---|---|---|---|---|---|---|---|")
        lines.extend(perf_variant_rows(args.perf_dir))
    text = "\n".join(lines)
    print(text)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text + "\n")


if __name__ == "__main__":
    main()
