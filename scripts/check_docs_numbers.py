#!/usr/bin/env python
"""Fail CI when figures quoted in the docs drift from BENCH_serving.json.

The README and docs/ARCHITECTURE.md quote representative benchmark
numbers ("~0.91 padding efficiency", "5.7x faster first token", ...).
Those figures are copied by hand from the committed BENCH_serving.json,
and hand-copied numbers rot: the bench gets re-run, the JSON gets
re-committed, the prose keeps bragging about last month's speedup.

This script pins every quoted figure to the JSON value it came from.
Each CHECK names a doc file, a regex with one capture group around the
quoted number, an expression over the loaded JSON (`d`), and a relative
tolerance covering prose rounding ("~0.91" for 0.9129).  It fails when:

  * the regex no longer matches (the sentence was edited or deleted —
    update CHECKS to match the new prose), or
  * the quoted number is outside tolerance of the JSON value (the bench
    was re-run — update the prose).

Run from the repo root (CI runs it in the lint job, where the committed
BENCH_serving.json is intact — the test job overwrites its copy):

    python scripts/check_docs_numbers.py
"""
import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH = ROOT / "BENCH_serving.json"

# (doc path, human label, regex with ONE capture group, json expr, rel_tol)
CHECKS = [
    ("README.md", "mixed padding efficiency (ragged)",
     r"`padding_efficiency` ~(\d+\.\d+) vs",
     "d['padding_efficiency']['mixed_ragged']", 0.05),
    ("README.md", "mixed padding efficiency (rect)",
     r"`padding_efficiency` ~\d+\.\d+ vs ~(\d+\.\d+)",
     "d['padding_efficiency']['mixed_rect']", 0.10),
    ("README.md", "long_prompt TTFT speedup",
     r"\*\*(\d+(?:\.\d+)?)x faster first token\*\*",
     "d['speedups']['ttft_long_prompt']", 0.10),
    ("README.md", "prefix_heavy unified tok/s",
     r"numbers: (\d+) vs \d+ tok/s",
     "d['scenarios']['prefix_heavy']['unified']['tok_s']", 0.05),
    ("README.md", "prefix_heavy baseline tok/s",
     r"numbers: \d+ vs (\d+) tok/s",
     "d['scenarios']['prefix_heavy']['pr1']['tok_s']", 0.05),
    ("README.md", "prefix_heavy speedup",
     r"throughput \(\*\*(\d+(?:\.\d+)?)x\*\*\)",
     "d['speedups']['throughput_prefix_heavy']", 0.10),
    ("README.md", "decode_heavy spec speedup",
     r"~(\d+(?:\.\d+)?)x decode throughput",
     "d['speedups']['decode_heavy_spec_vs_nonspec']", 0.10),
    ("README.md", "decode_heavy draft acceptance",
     r"at ~(\d+\.\d+) draft\s+acceptance",
     "d['scenarios']['decode_heavy']['spec']['draft_acceptance_rate']",
     0.10),
    ("README.md", "decode_heavy accepted per verification",
     r"~(\d+(?:\.\d+)?) tokens accepted per verification",
     "d['scenarios']['decode_heavy']['spec']['accepted_per_spec_step']",
     0.10),
    ("README.md", "disaggregated dedup savings",
     r"dedup saves ~(\d+)% of\s+shipped bytes",
     "100 * d['scenarios']['disaggregated']['dedup_savings']", 0.10),
    ("README.md", "oversubscribed swap-vs-recompute speedup",
     r"swap serves ~(\d+(?:\.\d+)?)x the recompute",
     "d['speedups']['oversubscribed_swap_vs_recompute']", 0.15),
    ("README.md", "open_loop goodput at half capacity",
     r"goodput holds ~(\d+\.\d+) of\s+offered at half capacity",
     "next(p for p in d['scenarios']['open_loop']['points'] "
     "if p['load_x'] == 0.5)['goodput_ratio']", 0.05),
    ("README.md", "weak_scaling single-core aggregate ratio",
     r"its ratio\s+\(~(\d+\.\d+)x\) is the host-overhead floor",
     "d['scenarios']['weak_scaling']['aggregate_ratio']", 0.10),
    ("docs/ARCHITECTURE.md", "oversubscribed swap-vs-recompute speedup",
     r"\*\*~(\d+(?:\.\d+)?)x\s+decode throughput\*\*",
     "d['speedups']['oversubscribed_swap_vs_recompute']", 0.15),
    ("docs/ARCHITECTURE.md", "mixed padding efficiency (ragged)",
     r"at\s+~(\d+\.\d+) ragged vs",
     "d['padding_efficiency']['mixed_ragged']", 0.05),
    ("docs/ARCHITECTURE.md", "mixed padding efficiency (rect)",
     r"ragged vs ~(\d+\.\d+) rectangular",
     "d['padding_efficiency']['mixed_rect']", 0.10),
]


def main() -> int:
    d = json.loads(BENCH.read_text())
    failures = []
    for relpath, label, pattern, expr, tol in CHECKS:
        text = (ROOT / relpath).read_text()
        m = re.search(pattern, text)
        if not m:
            failures.append(f"{relpath}: pattern for '{label}' not found "
                            f"(prose edited? update CHECKS): /{pattern}/")
            continue
        quoted = float(m.group(1))
        actual = float(eval(expr, {"d": d}))  # noqa: S307 — our own exprs
        rel = abs(quoted - actual) / max(abs(actual), 1e-12)
        status = "ok" if rel <= tol else "DRIFT"
        print(f"{status:5s} {relpath}: {label}: quoted {quoted:g} "
              f"vs bench {actual:.4g} (rel err {rel:.1%}, tol {tol:.0%})")
        if rel > tol:
            failures.append(
                f"{relpath}: '{label}' quotes {quoted:g} but "
                f"BENCH_serving.json says {actual:.4g} "
                f"(off by {rel:.1%}, tolerance {tol:.0%}) — update the "
                "prose or re-commit the bench")
    if failures:
        print("\n" + "\n".join(f"FAIL: {f}" for f in failures))
        return 1
    print(f"\nall {len(CHECKS)} quoted figures match BENCH_serving.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
