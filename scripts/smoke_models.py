"""Quick dev harness: run every assigned arch's smoke variant fwd + decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model

only = sys.argv[1:] or ASSIGNED_ARCHS
for name in only:
    cfg = get_config(name).smoke_variant()
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_positions, cfg.frontend.d_embed))
        loss, met = m.loss(params, batch)
    elif cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend.n_tokens, cfg.frontend.d_embed))
        loss, met = m.loss(params, batch)
    else:
        loss, met = m.loss(params, batch)
    assert jnp.isfinite(loss), (name, loss)

    # decode one token
    cache = m.init_cache(B, 64)
    logits, cache = m.decode_step(params, cache, tokens[:, :1])
    assert logits.shape == (B, 1, cfg.vocab_size), (name, logits.shape)
    assert jnp.all(jnp.isfinite(logits)), name
    print(f"OK {name:26s} loss={float(loss):.4f}")
print("all smoke OK")
