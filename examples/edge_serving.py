"""Edge serving walkthrough: from edge inference to DC-disaggregated LLMs.

The paper's workflow keeps a fast model *at* the instrument and ships the
heavy compute to a remote DCAI system, accepting the transfer cost when
the compute win covers it.  This example walks that idea through the
serving stack in four stages:

  1. **BraggNN at the edge** — the paper's edge-AI inference op, served
     through `BatchEngine` (stateless dynamic micro-batching).
  2. **One-engine LLM baseline** — a shared-system-prompt fleet (the
     federated real-time shape: every request opens with the facility's
     standing analysis preamble) served locally by one
     `PagedDecodeEngine`: chunked prefill, prefix-cache sharing,
     copy-on-write forks, speculative decode.
  3. **Disaggregated serving** — the same fleet split across two engines
     by `DisaggregatedEngine`: prefill in the data center, the prompt's
     paged-KV blocks shipped over the WAN as content-hashed
     `KVShipment`s priced by the paper's §4.1 transfer cost model, and
     decode at the edge.  Greedy decoding makes the output exactly
     token-identical to stage 2, and the prefix cache doubles as the
     transfer dedup layer — the shared preamble crosses the WAN once.
  4. **Prefix-cache persistence** — the wire format is also the snapshot
     format: the edge engine's cache is saved, a "restarted" engine
     loads it, and a warm prompt serves with cache hits and unchanged
     tokens.

Run: PYTHONPATH=src python examples/edge_serving.py
See docs/ARCHITECTURE.md §5 for the wire-format and coordinator design.
"""
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import BraggNNConfig, get_config
from repro.data.synthetic import bragg_patches
from repro.models import braggnn, build_model
from repro.serving import BatchEngine, DisaggregatedEngine, PagedDecodeEngine

# One smoke-size model, one fleet shape, reused by stages 2-4.
N_REQUESTS, MAX_NEW, PREAMBLE_LEN = 8, 8, 32


def serve_braggnn() -> None:
    """Stage 1: the paper's edge inference op under dynamic batching."""
    cfg = BraggNNConfig()
    params = braggnn.init_params(jax.random.PRNGKey(0), cfg)
    eng = BatchEngine(lambda p, x: braggnn.forward(p, x, cfg), params,
                      max_batch=256)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    total = 0
    for i in range(8):                      # ragged request sizes
        n = int(rng.integers(3, 300))
        d = bragg_patches(jax.random.PRNGKey(i), n)
        out = eng.infer(np.asarray(d["patches"]))
        assert out.shape == (n, 2)
        total += n
    dt = time.perf_counter() - t0
    print(f"[1] BraggNN BatchEngine: {eng.stats.summary()} "
          f"({total / dt:.0f} peaks/s incl. compile)")


def build_fleet(vocab_size: int):
    """A shared-system-prompt fleet: N requests, one standing preamble.

    Deterministic seeds so stage 2 and stage 3 serve *the same* prompts —
    the whole point is comparing their outputs token for token.
    """
    rng = np.random.default_rng(2)
    preamble = rng.integers(0, vocab_size, PREAMBLE_LEN).astype(np.int32)
    gen = np.random.default_rng(3)
    return [np.concatenate(
        [preamble, gen.integers(0, vocab_size, 5).astype(np.int32)])
        for _ in range(N_REQUESTS)]


def make_engine(api, params):
    """One edge-shaped paged engine (same knobs for every stage)."""
    return PagedDecodeEngine(api, params, n_slots=2, cache_len=128,
                             block_size=8, chunk_tokens=16,
                             prefix_cache=True)


def serve_one_engine(api, params, prompts):
    """Stage 2: the local baseline every later stage is measured against."""
    warm = make_engine(api, params)     # pay jit compiles outside the timing
    for p in prompts:
        warm.submit(p, MAX_NEW)
    warm.run_until_drained()

    eng = make_engine(api, params)
    for p in prompts:
        eng.submit(p, MAX_NEW)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    s = eng.stats()
    print(f"[2] one-engine baseline: {len(done)} requests in "
          f"{eng.steps} steps, {wall:.2f}s wall; prefix cache reused "
          f"{s['prefix_tokens_reused']} prompt tokens "
          f"({s['prefix_hits']} hits, {s['cow_copies']} CoW copies)")
    return {r.request_id: r.generated for r in done}, wall


def serve_disaggregated(api, params, prompts, baseline, base_wall):
    """Stage 3: DC prefill -> KV over the WAN -> edge decode."""
    # Two engines, two facilities.  dc_speedup models the DCAI accelerator
    # (measured prefill wall / 8 is charged to the shared SimClock); the
    # transfer itself is priced by the paper's T = x/v + S model over a
    # 10 Gbps DTN link with 48 ms RTT.
    dis = DisaggregatedEngine(make_engine(api, params),
                              make_engine(api, params),
                              nic_bps=1.25e9, dc_speedup=8.0)
    rids = [dis.submit(p, MAX_NEW) for p in prompts]
    done = {r.request_id: r.generated for r in dis.run_until_drained()}

    # The handoff is exact: shipped KV reproduces the prompt state, so
    # greedy decode emits the same tokens the one-engine baseline did.
    assert [done[r] for r in rids] == list(baseline.values())
    s = dis.stats()
    print(f"[3] disaggregated: {len(rids)} requests, token-identical "
          f"to the one-engine baseline")
    print(f"    shipped {s['bytes_shipped']:,} B vs {s['bytes_naive']:,} B "
          f"naive — dedup saved {s['dedup_savings']:.0%} "
          f"({s['blocks_dedup_skipped']} of "
          f"{s['blocks_exported']} blocks never crossed the WAN)")
    t = dis.priced_turnaround()
    print(f"    modeled turnaround: prefill {t['prefill']*1e3:.1f} ms "
          f"+ transfer {t['transfer']*1e3:.1f} ms "
          f"+ decode {t['decode']*1e3:.0f} ms = {t['total']*1e3:.0f} ms "
          f"(one-engine wall: {base_wall*1e3:.0f} ms)")
    xo = dis.crossover_bandwidth(base_wall)
    if xo is None:
        # Honest at smoke scale: prefill takes milliseconds, so the fixed
        # startup + RTT floor exceeds the modeled DC win at ANY bandwidth.
        floor = dis.priced_turnaround(1e18)["total"]
        print(f"    crossover: none — even an infinite link leaves a "
              f"{floor*1e3:.0f} ms floor; at smoke-model scale one-engine "
              "serving always wins (see crossover_analysis.py for when "
              "the split pays off)")
    else:
        print(f"    crossover: split wins above {xo:.3g} B/s")
    return dis


def persist_and_restart(api, params, dis, prompts, baseline) -> None:
    """Stage 4: the wire format doubles as cache persistence."""
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "prefix_cache.kvship")
        nbytes = dis.decode.save_prefix_cache(path)

        fresh = make_engine(api, params)        # the "restarted" engine
        loaded = fresh.load_prefix_cache(path)  # verifies every checksum
        fresh.submit(prompts[0], MAX_NEW)
        done = fresh.run_until_drained()
        s = fresh.stats()
    assert s["prefix_tokens_reused"] > 0
    assert done[0].generated == list(baseline.values())[0]
    print(f"[4] persistence: snapshot {nbytes:,} B, restarted engine "
          f"imported {loaded['imported']} blocks and served a warm prompt "
          f"with {s['prefix_tokens_reused']} tokens from cache, "
          "tokens unchanged")


def main() -> None:
    serve_braggnn()

    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = build_fleet(cfg.vocab_size)

    baseline, base_wall = serve_one_engine(api, params, prompts)
    dis = serve_disaggregated(api, params, prompts, baseline, base_wall)
    persist_and_restart(api, params, dis, prompts, baseline)
    print("edge_serving OK")


if __name__ == "__main__":
    main()
