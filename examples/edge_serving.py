"""Edge serving example: batched requests against two model kinds.

1. BraggNN via BatchEngine — the paper's edge-AI inference (stateless,
   dynamic micro-batching with padded compiled shapes).
2. An LLM (smoke-size gemma) via DecodeEngine — continuous batching over a
   paged KV cache (block pool + block tables + token-budget scheduler),
   demonstrating the serving substrate the decode input shapes
   (decode_32k / long_500k) exercise at production scale.

Run: PYTHONPATH=src python examples/edge_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import BraggNNConfig, get_config
from repro.data.synthetic import bragg_patches
from repro.models import braggnn, build_model
from repro.serving import BatchEngine, DecodeEngine


def serve_braggnn() -> None:
    cfg = BraggNNConfig()
    params = braggnn.init_params(jax.random.PRNGKey(0), cfg)
    eng = BatchEngine(lambda p, x: braggnn.forward(p, x, cfg), params,
                      max_batch=256)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    total = 0
    for i in range(8):                      # ragged request sizes
        n = int(rng.integers(3, 300))
        d = bragg_patches(jax.random.PRNGKey(i), n)
        out = eng.infer(np.asarray(d["patches"]))
        assert out.shape == (n, 2)
        total += n
    dt = time.perf_counter() - t0
    print(f"BraggNN BatchEngine: {eng.stats.summary()} "
          f"({total / dt:.0f} peaks/s incl. compile)")


def serve_llm() -> None:
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    window = api.effective_window(256)
    eng = DecodeEngine(api, params, n_slots=4, cache_len=256, window=window)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for _ in range(10):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=12)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    assert len(done) == 10
    print(f"LLM {type(eng).__name__}: {len(done)} requests, "
          f"{eng.tokens_decoded} tokens in {eng.steps} engine steps "
          f"({eng.tokens_decoded / dt:.1f} tok/s incl. compile)")
    print(f"  stats: {eng.stats()}")


if __name__ == "__main__":
    serve_braggnn()
    serve_llm()
    print("edge_serving OK")
