"""Edge serving example: batched requests against two model kinds.

1. BraggNN via BatchEngine — the paper's edge-AI inference (stateless,
   dynamic micro-batching with padded compiled shapes).
2. An LLM (smoke-size gemma) via DecodeEngine — continuous batching over a
   paged KV cache (block pool + block tables + unified token-budget
   scheduler), demonstrating the serving substrate the decode input shapes
   (decode_32k / long_500k) exercise at production scale.
3. A shared-system-prompt fleet — every request opens with the same
   preamble (the facility's standing analysis instructions), the shape the
   federated real-time workflows produce.  The prefix cache forks the
   preamble's KV blocks copy-on-write instead of re-prefilling them, and
   the demo prints the measured hit rate and per-request prefill savings.

Run: PYTHONPATH=src python examples/edge_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import BraggNNConfig, get_config
from repro.data.synthetic import bragg_patches
from repro.models import braggnn, build_model
from repro.serving import BatchEngine, DecodeEngine, PagedDecodeEngine


def serve_braggnn() -> None:
    cfg = BraggNNConfig()
    params = braggnn.init_params(jax.random.PRNGKey(0), cfg)
    eng = BatchEngine(lambda p, x: braggnn.forward(p, x, cfg), params,
                      max_batch=256)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    total = 0
    for i in range(8):                      # ragged request sizes
        n = int(rng.integers(3, 300))
        d = bragg_patches(jax.random.PRNGKey(i), n)
        out = eng.infer(np.asarray(d["patches"]))
        assert out.shape == (n, 2)
        total += n
    dt = time.perf_counter() - t0
    print(f"BraggNN BatchEngine: {eng.stats.summary()} "
          f"({total / dt:.0f} peaks/s incl. compile)")


def serve_llm() -> None:
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    window = api.effective_window(256)
    eng = DecodeEngine(api, params, n_slots=4, cache_len=256, window=window)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for _ in range(10):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=12)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    assert len(done) == 10
    print(f"LLM {type(eng).__name__}: {len(done)} requests, "
          f"{eng.tokens_decoded} tokens in {eng.steps} engine steps "
          f"({eng.tokens_decoded / dt:.1f} tok/s incl. compile)")
    print(f"  stats: {eng.stats()}")


def serve_shared_prompt_fleet() -> None:
    """Every request opens with the facility's standing system prompt; the
    prefix cache shares its KV blocks copy-on-write across requests, so
    only the first request pays the preamble prefill."""
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    system_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    n_requests, max_new = 8, 8

    def run_fleet(prefix_cache: bool):
        eng = PagedDecodeEngine(api, params, n_slots=2, cache_len=128,
                                block_size=8, chunk_tokens=16,
                                prefix_cache=prefix_cache)
        gen = np.random.default_rng(3)
        for _ in range(n_requests):
            tail = gen.integers(0, cfg.vocab_size, 5).astype(np.int32)
            eng.submit(np.concatenate([system_prompt, tail]), max_new)
        done = eng.run_until_drained()
        assert len(done) == n_requests
        return eng, {r.request_id: r.generated for r in done}

    eng_on, out_on = run_fleet(True)
    eng_off, out_off = run_fleet(False)
    assert out_on == out_off            # sharing never changes outputs
    s = eng_on.stats()
    prompt_tokens = n_requests * (len(system_prompt) + 5)
    hit_rate = s["prefix_tokens_reused"] / prompt_tokens
    saved = s["prefix_tokens_reused"] / n_requests
    print(f"shared-prompt fleet: {n_requests} requests x "
          f"{len(system_prompt)}-token system prompt")
    print(f"  prefix cache ON:  {eng_on.steps} steps, "
          f"{eng_on.tokens_prefilled} prefill tokens, "
          f"{s['prefix_hits']} hits, {s['cow_copies']} CoW copies")
    print(f"  prefix cache OFF: {eng_off.steps} steps, "
          f"{eng_off.tokens_prefilled} prefill tokens")
    print(f"  hit rate {hit_rate:.0%} of prompt tokens; "
          f"~{saved:.0f} prefill tokens saved per request")
    assert s["prefix_tokens_reused"] > 0
    assert eng_on.tokens_prefilled < eng_off.tokens_prefilled


if __name__ == "__main__":
    serve_braggnn()
    serve_llm()
    serve_shared_prompt_fleet()
    print("edge_serving OK")
