"""Figure-4 style decision analysis (the paper's §4.2 'model based analysis').

Uses the analytical cost model to decide, for a given experiment, whether to
run conventional analysis or the ML-surrogate workflow — and shows how the
decision shifts with the labeled fraction p and the DCAI training time.

Run: PYTHONPATH=src python examples/crossover_analysis.py
"""
from repro.core import build_system


def main() -> None:
    cm = build_system().costmodel

    print("N peaks      conventional@DC   ML surrogate    winner")
    for n in (10**4, 10**5, 10**6, 10**7, 10**8, 10**9):
        conv = cm.f_conventional_dc(n)
        ml = cm.f_ml(n, p=0.1)
        win = "ML" if ml.total < conv.total else "conventional"
        print(f"{n:9.0e}   {conv.total:12.1f}s   {ml.total:12.1f}s    {win}")

    n_star = cm.crossover(p=0.1)
    print(f"\ncrossover N* = {n_star:,} peaks (p=10%, T=19s Cerebras)")

    print("\nsensitivity:")
    import dataclasses
    for p in (0.02, 0.05, 0.1, 0.2):
        print(f"  p={p:4.2f}: N* = {cm.crossover(p=p):,}")
    names = {6.0: "Cerebras (CookieNetAE)", 19.0: "Cerebras (BraggNN)",
             139.0: "SambaNova 1-RDU", 1102.0: "local V100"}
    for t in (6.0, 19.0, 139.0, 1102.0):
        cm2 = build_system().costmodel
        cm2.costs = dataclasses.replace(cm2.costs, train=t)
        print(f"  T={t:7.1f}s: N* = {cm2.crossover(p=0.1):,}  ({names[t]})")

    # decision advice for a typical HEDM scan
    for n in (5 * 10**5, 5 * 10**7):
        print(f"\nadvise(N={n:.0e}): {cm.advise(n)}")


if __name__ == "__main__":
    main()
