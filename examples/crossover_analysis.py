"""Crossover analysis: when does shipping work to the DC beat staying local?

The paper answers this twice, and so does this walkthrough:

  1. **§4.2, training** (the original Figure-4 analysis): conventional
     peak analysis at the DC vs the ML-surrogate workflow, as a function
     of the number of Bragg peaks N and the DCAI training time T.
  2. **Serving** (this repo's extension): one-engine local serving vs
     the disaggregated split — prefill in the data center, paged-KV
     blocks over the WAN, decode at the edge.  Both sides of the
     comparison come from *one* served fleet: `DisaggregatedEngine`
     records every shipment, so `priced_turnaround(nic_bps)` re-prices
     the run at any link bandwidth without re-running the model.  The
     printed table is plot-ready turnaround-vs-bandwidth data, and
     `crossover_bandwidth()` bisects for the break-even link.
  3. **Serving at production scale** (modeled): the same §4.1 transfer
     model applied to a 7B-class workload (GQA KV at fp16, long
     prompts), where prefill is minutes, not milliseconds — the regime
     the paper's deployment actually lives in, and where the split wins
     decisively at the paper's 10 Gbps DTN link.

Run: PYTHONPATH=src python examples/crossover_analysis.py
See docs/ARCHITECTURE.md §5 for the wire-format and coordinator design.
"""
import dataclasses
import math
import time

import numpy as np

from repro.core import build_system
from repro.serving.transfer import edge_dc_topology

# --- stage 2/3 knobs ------------------------------------------------------
BW_SWEEP = (1e5, 1e6, 1e7, 1e8, 1.25e9, 1e10)   # bytes/s, DTN NIC = 1.25e9
DC_SPEEDUP = 8.0                                 # modeled DCAI : edge ratio

# --- stage 3: a 7B-class production workload (modeled) --------------------
KV_BYTES_PER_TOKEN = 2 * 32 * 8 * 128 * 2   # k+v, 32 layers, GQA 8x128, fp16
EDGE_PREFILL_TOK_S = 1_000.0                # edge-GPU 7B prefill throughput
WIRE_BLOCK_TOKENS = 256                     # tokens per shipped payload file
DECODE_S = 10.0                             # decode wall, identical both ways


def training_crossover() -> None:
    """Stage 1: the paper's §4.2 model-based analysis, unchanged."""
    cm = build_system().costmodel

    print("[1] training crossover (paper §4.2, Figure-4 style)")
    print("    N peaks    conventional@DC   ML surrogate    winner")
    for n in (10**4, 10**5, 10**6, 10**7, 10**8, 10**9):
        conv = cm.f_conventional_dc(n)
        ml = cm.f_ml(n, p=0.1)
        win = "ML" if ml.total < conv.total else "conventional"
        print(f"    {n:7.0e}   {conv.total:12.1f}s   {ml.total:11.1f}s"
              f"    {win}")
    print(f"    crossover N* = {cm.crossover(p=0.1):,} peaks "
          "(p=10%, T=19s Cerebras)")
    for p in (0.02, 0.05, 0.1, 0.2):
        print(f"      p={p:4.2f}: N* = {cm.crossover(p=p):,}")
    names = {6.0: "Cerebras (CookieNetAE)", 19.0: "Cerebras (BraggNN)",
             139.0: "SambaNova 1-RDU", 1102.0: "local V100"}
    for t in (6.0, 19.0, 139.0, 1102.0):
        cm2 = build_system().costmodel
        cm2.costs = dataclasses.replace(cm2.costs, train=t)
        print(f"      T={t:7.1f}s: N* = {cm2.crossover(p=0.1):,}"
              f"  ({names[t]})")


def serving_crossover_measured() -> None:
    """Stage 2: serve one fleet both ways, re-price across bandwidths."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import DisaggregatedEngine, PagedDecodeEngine

    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    # a shared-preamble fleet (the federated real-time shape)
    rng = np.random.default_rng(11)
    preamble = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    prompts = [np.concatenate(
        [preamble, rng.integers(0, cfg.vocab_size, 6).astype(np.int32)])
        for _ in range(6)]

    def make():
        return PagedDecodeEngine(api, params, n_slots=2, cache_len=128,
                                 block_size=8, chunk_tokens=16,
                                 prefix_cache=True)

    # pay jit compiles outside the timed comparison
    warm = make()
    for p in prompts:
        warm.submit(p, 8)
    warm.run_until_drained()

    # one-engine baseline
    base = make()
    ids = [base.submit(p, 8) for p in prompts]
    t0 = time.perf_counter()
    ref = {r.request_id: r.generated for r in base.run_until_drained()}
    base_wall = time.perf_counter() - t0

    # disaggregated: same prompts, two engines, the §4.1 cost model
    dis = DisaggregatedEngine(make(), make(), nic_bps=1.25e9,
                              dc_speedup=DC_SPEEDUP)
    rids = [dis.submit(p, 8) for p in prompts]
    done = {r.request_id: r.generated for r in dis.run_until_drained()}
    assert [done[r] for r in rids] == [ref[i] for i in ids]

    s = dis.stats()
    print(f"\n[2] serving crossover, measured (smoke model, "
          f"{len(prompts)} requests)")
    print(f"    token-identical to one-engine; dedup saved "
          f"{s['dedup_savings']:.0%} of shipped bytes")
    print("    link B/s     prefill_s  transfer_s  decode_s   total_s"
          "   vs local")
    for bw in BW_SWEEP:                       # plot-ready sweep data
        t = dis.priced_turnaround(bw)
        verdict = "split" if t["total"] <= base_wall else "local"
        print(f"    {bw:8.0e}   {t['prefill']:9.3f} {t['transfer']:11.3f}"
              f" {t['decode']:9.3f} {t['total']:9.3f}   {verdict}")
    print(f"    one-engine baseline: {base_wall:.3f}s")
    xo = dis.crossover_bandwidth(base_wall)
    if xo is None:
        floor = dis.priced_turnaround(1e18)["total"]
        print(f"    crossover: none — infinite-bandwidth floor "
              f"{floor:.3f}s still loses; serve locally at this scale")
    else:
        print(f"    crossover: split wins above {xo:.3g} B/s "
              f"({'below' if xo <= 1.25e9 else 'ABOVE'} the paper's "
              "1.25e9 B/s DTN link)")


def _modeled_split(prompt_tokens: int, nic_bps: float) -> dict:
    """Price a production-scale split with the §4.1 model.

    Edge prefill wall is ``tokens / EDGE_PREFILL_TOK_S``; the DC runs it
    ``DC_SPEEDUP``x faster; the prompt's KV
    (``tokens * KV_BYTES_PER_TOKEN``) crosses the WAN as one manifest
    plus one payload file per ``WIRE_BLOCK_TOKENS`` tokens, exactly how
    `DisaggregatedEngine` files its shipments.
    """
    link = edge_dc_topology(nic_bps).link("dc", "edge")
    prefill_edge = prompt_tokens / EDGE_PREFILL_TOK_S
    n_files = 1 + math.ceil(prompt_tokens / WIRE_BLOCK_TOKENS)
    conc = min(8, n_files)
    xfer = (prompt_tokens * KV_BYTES_PER_TOKEN / link.effective_rate(conc)
            + link.per_file_startup * math.ceil(n_files / conc)
            + 2 * link.rtt)
    local = prefill_edge + DECODE_S
    split = prefill_edge / DC_SPEEDUP + xfer + DECODE_S
    return {"local": local, "split": split, "transfer": xfer}


def serving_crossover_modeled() -> None:
    """Stage 3: the same model at production scale, where the split wins."""
    print("\n[3] serving crossover, modeled (7B-class KV, "
          f"{KV_BYTES_PER_TOKEN} B/token, edge prefill "
          f"{EDGE_PREFILL_TOK_S:.0f} tok/s, DC {DC_SPEEDUP:.0f}x)")
    print("    prompt tok    local_s    split_s   (transfer_s)   winner")
    for n in (1_000, 10_000, 50_000, 100_000, 500_000):
        m = _modeled_split(n, nic_bps=1.25e9)
        win = "split" if m["split"] < m["local"] else "local"
        print(f"    {n:10,} {m['local']:10.1f} {m['split']:10.1f}"
              f"   ({m['transfer']:8.1f})     {win}")
    m = _modeled_split(500_000, nic_bps=1.25e9)
    print(f"    at the 500k-token long-prompt shape the split wins "
          f"{m['local'] / m['split']:.1f}x on the paper's 10 Gbps link")


if __name__ == "__main__":
    training_crossover()
    serving_crossover_measured()
    serving_crossover_modeled()
    print("crossover_analysis OK")
