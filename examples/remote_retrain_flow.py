"""End-to-end driver (deliverable b): the paper's geographically distributed
(re)training workflow, with REAL training for a few hundred steps.

Scenario (paper Fig. 1/2): an experiment at SLAC collects new Bragg-peak
data; the DNNTrainerFlow ships it to the data center, retrains BraggNN for
300 steps (REAL training, executed here), ships the model back, registers it
in the edge model repository, and serves it on the edge BatchEngine.  The
clock decomposes turnaround into real-compute vs simulated-WAN seconds.

A second run demonstrates the repository's warm-start (paper future-work 1):
the new flow picks the best prior model as its foundation and fine-tunes.

Run: PYTHONPATH=src python examples/remote_retrain_flow.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import label_for_braggnn
from repro.configs import BraggNNConfig
from repro.core import build_system, dnn_trainer_flow
from repro.core.transfer import FileRef
from repro.data.synthetic import bragg_patches
from repro.models import braggnn
from repro.optim import adam
from repro.serving import BatchEngine


def make_train_function(sys_, steps, artifact_name, warm_start_from=None):
    cfg = BraggNNConfig()

    def train(dataset_name: str):
        key = jax.random.PRNGKey(0)
        if warm_start_from is not None:
            params = warm_start_from
            print("    [dc] warm-starting from repository model")
        else:
            params = braggnn.init_params(key, cfg)
        opt = adam(1e-3)
        opt_state = opt.init(params)

        # "dataset" = the transferred raw patches; labeled at the DC (A op)
        raw = sys_.store.get("alcf", dataset_name).payload

        @jax.jit
        def step(p, s, batch):
            (l, _), g = jax.value_and_grad(
                lambda p_: braggnn.loss_fn(p_, batch, cfg),
                has_aux=True)(p)
            p2, s2 = opt.update(g, s, p)
            return p2, s2, l

        n = raw["patches"].shape[0]
        bs = 64
        for i in range(steps):
            lo = (i * bs) % (n - bs)
            batch = {"patches": raw["patches"][lo:lo + bs],
                     "centers": raw["labels"][lo:lo + bs]}
            params, opt_state, loss = step(params, opt_state, batch)
        val = float(loss)
        sys_.store.put("alcf", FileRef(artifact_name, 3_000_000,
                                       payload=params))
        return {"final_loss": val, "steps": steps}

    return sys_.funcx.register_function(train, "train_braggnn")


def run_flow(sys_, steps, version_tag, warm_start=None):
    tok = sys_.user_token()
    cfg = BraggNNConfig()

    # experiment collects + labels a dataset at the edge facility
    key = jax.random.PRNGKey(42 if version_tag == "v1" else 43)
    d = bragg_patches(key, 4096)
    labels = label_for_braggnn(d["patches"])
    sys_.store.put("slac", FileRef(
        "new_scan.h5", int(d["patches"].size * 4),
        payload={"patches": d["patches"], "labels": labels}))

    fid = make_train_function(sys_, steps, "braggnn_new.npz",
                              warm_start_from=warm_start)
    eid = sys_.funcx.register_endpoint("tpu-v5e-pod", mode="real")
    flow_id = sys_.flows.deploy(dnn_trainer_flow())

    t_wall = time.perf_counter()
    run = sys_.flows.run(flow_id, {
        "src": "slac", "dc": "alcf", "dataset": ["new_scan.h5"],
        "train_endpoint": eid, "train_function": fid,
        "train_args": ["new_scan.h5"], "train_kwargs": {},
        "modeled_duration": None,
        "model_artifacts": ["braggnn_new.npz"],
        "model_name": "braggnn_new.npz",
        "register_as": "braggnn", "version_tag": version_tag,
        "metrics": {"val_loss":
                    0.0},  # filled from the train result below
    }, tok)
    wall = time.perf_counter() - t_wall
    assert run.status == "SUCCEEDED", [e.error for e in run.log]
    train_res = run.output["TrainModel"]["result"]
    print(f"flow {version_tag}: status={run.status} "
          f"turnaround={run.turnaround:.1f}s (wall {wall:.1f}s)")
    for e in run.log:
        print(f"  {e.state:14s} {e.duration:7.2f}s")
    print(f"  train final_loss={train_res['final_loss']:.5f} "
          f"({train_res['steps']} steps)")
    # fix up registered metrics
    entry = sys_.repo.latest("braggnn")
    entry.metrics["val_loss"] = train_res["final_loss"]
    return run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    sys_ = build_system()
    cfg = BraggNNConfig()

    # --- run 1: train from scratch through the distributed workflow -------
    run_flow(sys_, args.steps, "v1")

    # --- run 2: retrain with repository warm-start (future-work #1) -------
    best = sys_.repo.best_foundation("braggnn", "val_loss")
    warm = best.artifact.payload
    run_flow(sys_, max(args.steps // 3, 50), "v2-warmstart", warm_start=warm)

    br = sys_.clock.breakdown()
    print(f"clock: real={br['real']:.1f}s sim(WAN+svc)={br['sim']:.1f}s "
          f"total={br['total']:.1f}s")

    # --- deploy at the edge: serve with the BatchEngine (E op) ------------
    model = sys_.repo.latest("braggnn").artifact.payload
    eng = BatchEngine(lambda p, x: braggnn.forward(p, x, cfg), model)
    test = bragg_patches(jax.random.PRNGKey(7), 512)
    pred = eng.infer(np.asarray(test["patches"]))
    err = float(np.abs(pred - np.asarray(test["centers"])).mean()) * 10
    print(f"edge serving: {eng.stats.summary()}  mean err {err:.3f} px")
    assert err < 0.6
    print("remote_retrain_flow OK")


if __name__ == "__main__":
    main()
