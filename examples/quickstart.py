"""Quickstart: the paper's full loop on one host in ~a minute.

1. SIMULATE Bragg-peak data (the paper's S op),
2. LABEL it with the conventional pseudo-Voigt analysis (the A op —
   executed with the Pallas TPU kernel in interpret mode on CPU),
3. TRAIN BraggNN on the labeled data (the T op),
4. ESTIMATE peak centers with the trained surrogate (the E op)
   and compare against both the labels and the ground truth.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.analysis import label_for_braggnn
from repro.configs import BraggNNConfig
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import bragg_patches
from repro.models import braggnn
from repro.optim import adam
from repro.train import TrainerConfig, fit


def main() -> None:
    cfg = BraggNNConfig()
    key = jax.random.PRNGKey(0)
    params = braggnn.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"BraggNN: {n_params:,} params")

    # S + A: simulate patches, label with the conventional analysis
    def make_batch(k, bs):
        d = bragg_patches(k, bs)
        labels = label_for_braggnn(d["patches"])   # pseudo-Voigt kernel
        return {"patches": d["patches"], "centers": labels,
                "truth": d["centers"]}

    loader = ShardedLoader(make_batch, global_batch=64, prefetch=0)

    # T: train
    state, hist = fit(lambda p, b: braggnn.loss_fn(p, b, cfg), adam(1e-3),
                      params, iter(loader),
                      TrainerConfig(steps=150, log_every=25),
                      callbacks=[lambda s, m: print(
                          f"  step {s:4d} loss {float(m['loss']):.5f}")])

    # E: estimate on fresh data; compare vs labels and ground truth
    test = make_batch(jax.random.PRNGKey(999), 256)
    pred = braggnn.forward(state.params, test["patches"], cfg)
    patch_px = cfg.patch - 1
    err_vs_label = float(jnp.abs(pred - test["centers"]).mean()) * patch_px
    err_vs_truth = float(jnp.abs(pred - test["truth"]).mean()) * patch_px
    print(f"E: mean |err| vs pseudo-Voigt labels: {err_vs_label:.3f} px")
    print(f"E: mean |err| vs ground truth:        {err_vs_truth:.3f} px")
    assert err_vs_truth < 0.5, "surrogate failed to learn peak localization"
    print("quickstart OK")


if __name__ == "__main__":
    main()
