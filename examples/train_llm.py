"""Train a (reduced) assigned-architecture LLM end-to-end on this host.

Any of the 10 assigned architectures is selectable via --arch; the model is
the reduced smoke variant by default (CPU-friendly) or --full on real
hardware.  Demonstrates: sharded data pipeline -> pjit train step with the
production sharding rules -> checkpoint save/restore -> loss goes down.

Run: PYTHONPATH=src python examples/train_llm.py --arch deepseek-moe-16b \
         --steps 60
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import InputShape
from repro.data.synthetic import lm_token_batch
from repro.launch import sharding as shard_lib
from repro.launch import specs as specs_lib
from repro.launch.mesh import data_axes_of, make_host_mesh, mesh_axis_sizes
from repro.models import build_model
from repro.train import checkpoint as ckpt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke_variant()
    api = build_model(cfg)
    shape = InputShape("example", args.seq, args.batch, "train")
    mesh = make_host_mesh()
    axes = mesh_axis_sizes(mesh)
    key = jax.random.PRNGKey(0)

    with jax.set_mesh(mesh):
        params = api.init(key)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"{cfg.name} ({cfg.family}): {n / 1e6:.2f}M params, "
              f"mesh {dict(axes)}")
        pspecs = shard_lib.param_specs(params, axes, data_axes_of(mesh))
        params = jax.device_put(params, shard_lib.to_named(pspecs, mesh))

        step_fn, opt = specs_lib.make_train_step_fn(api, shape, lr=args.lr)
        opt_state = opt.init(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        losses = []
        t0 = time.perf_counter()
        for step in range(1, args.steps + 1):
            bkey = jax.random.fold_in(key, step)
            batch = lm_token_batch(bkey, args.batch, args.seq,
                                   cfg.vocab_size)
            if cfg.family == "audio":
                batch["frames"] = jax.random.normal(
                    bkey, (args.batch, cfg.encoder_positions,
                           cfg.frontend.d_embed), jnp.bfloat16)
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    bkey, (args.batch, cfg.frontend.n_tokens,
                           cfg.frontend.d_embed), jnp.bfloat16)
            params, opt_state, m = jitted(params, opt_state, batch)
            if step % 10 == 0 or step in (1, args.steps):
                losses.append(float(m["loss"]))
                print(f"  step {step:4d}  loss {losses[-1]:.4f}")

        assert losses[-1] < losses[0], "loss did not decrease"
        dt = time.perf_counter() - t0
        print(f"{args.steps} steps in {dt:.1f}s "
              f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")

        # checkpoint roundtrip
        with tempfile.TemporaryDirectory() as d:
            ckpt_lib.save_checkpoint(d, args.steps, {"params": params})
            restored, _ = ckpt_lib.restore_checkpoint(d, {"params": params})
            print(f"checkpoint roundtrip OK "
                  f"({ckpt_lib.tree_nbytes(restored) / 1e6:.1f} MB)")
    print("train_llm OK")


if __name__ == "__main__":
    main()
