"""Trainer loop + serving engines + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import BraggNNConfig
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import bragg_patches, cookiebox_shots, lm_token_batch
from repro.models import braggnn, build_model
from repro.optim import adam
from repro.serving import BatchEngine, DecodeEngine
from repro.train import TrainerConfig, fit, make_train_step


# ---------------------------------------------------------------------------
def test_fit_reduces_braggnn_loss(key):
    cfg = BraggNNConfig()
    params = braggnn.init_params(key, cfg)

    def make_batch(k, bs):
        d = bragg_patches(k, bs)
        return {"patches": d["patches"], "centers": d["centers"]}

    loader = ShardedLoader(make_batch, 32, prefetch=0)
    state, hist = fit(lambda p, b: braggnn.loss_fn(p, b, cfg), adam(1e-3),
                      params, iter(loader), TrainerConfig(steps=25,
                                                          log_every=5))
    losses = [l for _, l in hist["loss"]]
    assert losses[-1] < losses[0] * 0.7


def test_grad_accum_equivalence(key):
    """grad_accum=2 over a 2x batch == single big-batch step."""
    cfg = BraggNNConfig()
    params = braggnn.init_params(key, cfg)
    opt = adam(1e-3)
    d = bragg_patches(jax.random.fold_in(key, 1), 16)
    batch = {"patches": d["patches"], "centers": d["centers"]}

    s1 = make_train_step(lambda p, b: braggnn.loss_fn(p, b, cfg), opt,
                         grad_accum=1, donate=False)
    s2 = make_train_step(lambda p, b: braggnn.loss_fn(p, b, cfg), opt,
                         grad_accum=2, donate=False)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_sharded_loader_partitions_global_batch(key):
    def make_batch(k, bs):
        return {"x": jnp.arange(bs)}

    l0 = ShardedLoader(make_batch, 8, host_id=0, host_count=2, prefetch=0)
    l1 = ShardedLoader(make_batch, 8, host_id=1, host_count=2, prefetch=0)
    b0 = next(iter(l0))
    b1 = next(iter(l1))
    assert b0["x"].shape == (4,)
    combined = np.concatenate([np.asarray(b0["x"]), np.asarray(b1["x"])])
    np.testing.assert_array_equal(combined, np.arange(8))


def test_prefetch_stream_consistency():
    def make_batch(k, bs):
        return {"x": jax.random.normal(k, (bs, 3))}

    a = ShardedLoader(make_batch, 4, prefetch=0)
    b = ShardedLoader(make_batch, 4, prefetch=2)
    ita, itb = iter(a), iter(b)
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(next(ita)["x"]),
                                      np.asarray(next(itb)["x"]))


# ---------------------------------------------------------------------------
def test_batch_engine_padding_equivalence(key):
    cfg = BraggNNConfig()
    params = braggnn.init_params(key, cfg)
    eng = BatchEngine(lambda p, x: braggnn.forward(p, x, cfg), params,
                      max_batch=16)
    d = bragg_patches(key, 13)           # odd size forces padding
    out = eng.infer(np.asarray(d["patches"]))
    direct = braggnn.forward(params, d["patches"], cfg)
    np.testing.assert_allclose(out, np.asarray(direct), atol=1e-5)
    assert eng.stats.summary()["items"] == 13


def test_decode_engine_continuous_batching(key):
    from repro.configs import get_config
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(key)
    eng = DecodeEngine(api, params, n_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    for _ in range(5):                   # more requests than slots
        eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 6)
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.generated) == 6 for r in done)
    assert eng.tokens_decoded == 30


def test_synthetic_generators_shapes(key):
    d = bragg_patches(key, 8)
    assert d["patches"].shape == (8, 11, 11, 1)
    assert float(d["patches"].max()) <= 1.0
    c = cookiebox_shots(key, 4)
    assert c["images"].shape == (4, 16, 128, 1)
    np.testing.assert_allclose(np.asarray(c["targets"][..., 0].sum(-1)),
                               1.0, atol=1e-3)
    t = lm_token_batch(key, 2, 16, 100)
    assert t["tokens"].shape == (2, 16)
    assert int(t["labels"][0, -1]) == -1
