"""Scheduler admission / token-budget / preemption under tight block pools.

Pure host-side tests: the scheduler and KV manager are exercised without a
model — ``schedule()`` + manual cursor advancement stand in for the jitted
decode step.
"""
import numpy as np
import pytest

from repro.serving import KVCacheManager, Request, Scheduler, SchedulerConfig
from repro.serving.scheduler import RequestState


def make(n_lanes=2, num_blocks=9, block_size=2, max_blocks=4,
         token_budget=0):
    kv = KVCacheManager(num_blocks, block_size, max_blocks_per_seq=max_blocks)
    sched = Scheduler(SchedulerConfig(n_lanes=n_lanes,
                                      token_budget=token_budget), kv)
    return sched, kv


def req(rid, plen=3, max_new=4):
    return Request(rid, np.arange(plen, dtype=np.int32), max_new)


def advance(sched, decision):
    """Consume one token per scheduled request (the engine's role)."""
    for r in decision.scheduled:
        if r.cursor >= len(r.feed) - 1:
            r.generated.append(0)
            r.feed.append(0)
        r.cursor += 1


def test_admission_fills_lanes_fcfs():
    sched, kv = make(n_lanes=2)
    for i in range(4):
        sched.add(req(i))
    d = sched.schedule()
    assert d.n_admitted == 2
    assert [r.request_id for r in d.scheduled] == [0, 1]
    assert sched.lanes[0].request_id == 0
    assert sched.lanes[1].request_id == 1
    assert len(sched.waiting) == 2
    # every scheduled token got a KV slot
    assert kv.n_tokens(0) == 1 and kv.n_tokens(1) == 1


def test_token_budget_caps_admissions_and_prefers_decode():
    sched, kv = make(n_lanes=4, num_blocks=33, token_budget=2)
    sched.add(req(0, plen=1))            # 1-token prompt: decodes immediately
    d = sched.schedule()
    assert d.n_admitted == 1
    advance(sched, d)
    sched.add(req(1, plen=4))
    sched.add(req(2, plen=4))
    sched.add(req(3, plen=4))
    d = sched.schedule()
    # budget 2: the decode lane (req 0) runs, one prefill admission rides
    assert d.n_decode >= 1
    assert len(d.scheduled) == 2
    ids = {r.request_id for r in d.scheduled}
    assert 0 in ids and 1 in ids and 3 not in ids


def test_preemption_by_recompute_lifo():
    # pool: 4 usable blocks of 2 tokens; two lanes needing 3 blocks each
    sched, kv = make(n_lanes=2, num_blocks=5, block_size=2, max_blocks=3)
    sched.add(req(0, plen=4, max_new=2))
    sched.add(req(1, plen=4, max_new=2))
    preempted_seen = False
    for _ in range(40):
        if not sched.has_work():
            break
        d = sched.schedule()
        if d.n_preempted:
            preempted_seen = True
            # LIFO: the later-admitted request is the victim
            assert sched.waiting[0].request_id == 1
            assert sched.waiting[0].n_preemptions >= 1
            # victim's blocks came back to the pool or went to the survivor
            assert not kv.has_seq(1)
        advance(sched, d)
        for r in list(sched.running):
            if len(r.generated) >= r.max_new_tokens:
                sched.finish(r)
    assert preempted_seen
    assert all(r.done for r in [sched.lanes[0]] if r is not None) or \
        not sched.has_work()


def test_preempted_request_resumes_with_generated_kept():
    sched, kv = make(n_lanes=1, num_blocks=4, block_size=2, max_blocks=3)
    r = req(0, plen=2, max_new=3)
    sched.add(r)
    d = sched.schedule()
    advance(sched, d)
    d = sched.schedule()
    advance(sched, d)                     # emitted one token
    assert r.generated == [0]
    sched._preempt(r, d, [])
    assert r.state == RequestState.WAITING
    assert r.generated == [0]             # kept for recompute
    d = sched.schedule()
    assert d.n_admitted == 1
    assert r.feed == [0, 1, 0]            # prompt + generated replayed
    assert r.cursor == 0


def test_single_request_outgrowing_pool_raises():
    # prompt fits (2 blocks) so the request is admitted, but decode growth
    # needs a 3rd block and there is no victim to evict but itself
    sched, kv = make(n_lanes=1, num_blocks=3, block_size=2, max_blocks=4)
    sched.add(req(0, plen=3, max_new=4))
    with pytest.raises(RuntimeError):
        for _ in range(10):
            d = sched.schedule()
            advance(sched, d)


def test_oversized_prompt_never_admitted():
    sched, kv = make(n_lanes=1, num_blocks=3, block_size=2, max_blocks=4)
    sched.add(req(0, plen=6, max_new=2))  # needs 3 blocks, pool has 2
    d = sched.schedule()
    assert d.n_admitted == 0 and not d.scheduled
    assert sched.has_work()               # engine surfaces this as a stall


def test_admission_blocked_until_blocks_free():
    sched, kv = make(n_lanes=2, num_blocks=3, block_size=2, max_blocks=2)
    sched.add(req(0, plen=3, max_new=1))  # will occupy both usable blocks
    d = sched.schedule()
    advance(sched, d)
    d = sched.schedule()
    advance(sched, d)
    d = sched.schedule()                  # 3rd token -> 2nd block
    advance(sched, d)
    sched.add(req(1, plen=3, max_new=1))
    d = sched.schedule()
    assert d.n_admitted == 0              # no blocks for req 1 yet
    advance(sched, d)                     # req 0 emits its token
    sched.finish(sched.lanes[0])
    d = sched.schedule()
    assert d.n_admitted == 1              # blocks freed, req 1 admitted


# ---------------------------------------------------------------------------
# unified token-budget chunking
# ---------------------------------------------------------------------------
def test_chunked_prefill_schedules_multiple_tokens():
    sched, kv = make(n_lanes=2, num_blocks=17, block_size=2, max_blocks=8)
    sched.cfg.chunk_tokens = 4
    sched.add(req(0, plen=10))
    d = sched.schedule()
    assert d.num_scheduled[0] == 4                 # one chunk, not one token
    assert kv.n_tokens(0) == 4                     # every chunk token has KV
    for r in d.scheduled:
        r.cursor += d.num_scheduled[r.request_id]
    d = sched.schedule()
    assert d.num_scheduled[0] == 4
    assert d.n_prefill_tokens == 4 and d.n_decode_tokens == 0


def test_budget_shared_between_decodes_and_chunks():
    """One budget covers both phases: decodes are served first, the
    remaining budget goes to prefill chunks."""
    sched, kv = make(n_lanes=3, num_blocks=33, block_size=2, max_blocks=8,
                     token_budget=5)
    sched.cfg.chunk_tokens = 8
    sched.add(req(0, plen=1))                      # decodes immediately
    d = sched.schedule()
    for r in d.scheduled:
        if r.cursor >= len(r.feed) - 1:
            r.generated.append(0)
            r.feed.append(0)
        r.cursor += d.num_scheduled[r.request_id]
    sched.add(req(1, plen=12))
    d = sched.schedule()
    assert d.num_scheduled[0] == 1                 # the decode lane
    assert d.num_scheduled[1] == 4                 # budget 5 - 1 decode
    assert d.n_decode_tokens == 1 and d.n_prefill_tokens == 4


def test_mid_chunk_truncation_keeps_progress():
    """When the pool dries up mid-chunk and the victim would be the
    chunking request itself, the chunk is truncated instead of preempted:
    partial progress is kept and nobody is evicted."""
    sched, kv = make(n_lanes=2, num_blocks=6, block_size=2, max_blocks=8)
    sched.cfg.chunk_tokens = 8
    sched.add(req(0, plen=3))
    sched.add(req(1, plen=8))
    d = sched.schedule()
    assert d.num_scheduled[0] == 3                 # fits: 2 blocks
    assert 1 <= d.num_scheduled[1] < 8             # truncated mid-chunk
    assert d.num_scheduled[1] == kv.n_tokens(1)
    assert d.n_preempted == 0
    assert {r.request_id for r in sched.running} == {0, 1}


def test_admission_shares_cached_prefix():
    """With the prefix cache on, a re-admitted identical prompt skips its
    cached full blocks: the cursor starts past them."""
    kv = KVCacheManager(17, 2, max_blocks_per_seq=8,
                        enable_prefix_cache=True)
    sched = Scheduler(SchedulerConfig(n_lanes=1, chunk_tokens=8), kv)
    r0 = req(0, plen=6, max_new=1)
    sched.add(r0)
    d = sched.schedule()
    assert d.num_scheduled[0] == 6                 # whole prompt, one chunk
    r0.cursor += 6                                 # chunk end emits a token
    r0.generated.append(9)
    r0.feed.append(9)
    sched.finish(r0)
    r1 = req(1, plen=6, max_new=1)                 # same prompt tokens
    sched.add(r1)
    d = sched.schedule()
    assert d.n_admitted == 1
    assert d.prefix_cached_tokens == 5             # 6 aligned, capped at 5
    assert r1.cursor == 5
    assert d.num_scheduled[1] == 1                 # only the last token


# ---------------------------------------------------------------------------
# ragged flat-token scheduling (fill_to_bucket) invariants
# ---------------------------------------------------------------------------
def test_budget_invariant_holds_every_step():
    """sum(num_scheduled) <= token_budget and no lane scheduled past its
    prompt, across a full mixed drain with bucket fill on."""
    sched, kv = make(n_lanes=3, num_blocks=65, block_size=2, max_blocks=16,
                     token_budget=7)
    sched.cfg.chunk_tokens = 4
    sched.cfg.fill_to_bucket = True
    for i in range(5):
        sched.add(req(i, plen=3 + 5 * (i % 3), max_new=3))
    for _ in range(100):
        if not sched.has_work():
            break
        d = sched.schedule()
        assert sum(d.num_scheduled.values()) <= 7
        for r in d.scheduled:
            n = d.num_scheduled[r.request_id]
            assert 1 <= n
            assert r.cursor + n <= len(r.feed)
            assert kv.n_tokens(r.request_id) == r.cursor + n
        for r in d.scheduled:                  # chunk-aware engine stand-in
            n = d.num_scheduled[r.request_id]
            if r.cursor + n == len(r.feed):
                r.generated.append(0)
                r.feed.append(0)
            r.cursor += n
        for r in list(sched.running):
            if len(r.generated) >= r.max_new_tokens:
                sched.finish(r)
    assert not sched.has_work()


def test_one_decode_plus_prefill_fills_exactly_256_flat_slots():
    """The padding-waste regression: a 1-token decode sharing a step with
    a 255-token prefill chunk must produce a flat batch of exactly 256
    slots — zero padding, where the rectangular layout would have padded
    the decode lane to 256 (2 * 256 = 512 slots, 50% waste floor)."""
    from repro.serving import RaggedBatch
    kv = KVCacheManager(600, 2, max_blocks_per_seq=300)
    sched = Scheduler(SchedulerConfig(n_lanes=2, token_budget=256,
                                      chunk_tokens=255,
                                      fill_to_bucket=True), kv)
    r0 = req(0, plen=1, max_new=4)            # 1-token prompt: decode lane
    sched.add(r0)
    d = sched.schedule()
    advance(sched, d)                          # r0 emitted: now decoding
    sched.add(req(1, plen=400, max_new=1))     # long prefill
    d = sched.schedule()
    assert d.num_scheduled[0] == 1             # the decode
    assert d.num_scheduled[1] == 255           # the chunk
    batch = RaggedBatch.build(d, kv, 2, 2, cap=256)
    assert batch.total_tokens == 256
    assert batch.padded_tokens == 256          # exactly, no pow2 blow-up
    assert batch.padding_efficiency == 1.0


def test_bucket_fill_extends_chunk_to_pow2_boundary():
    """When a step's total lands between buckets, prefill chunks are
    extended so the padding slots carry real prompt tokens instead."""
    sched, kv = make(n_lanes=2, num_blocks=129, block_size=2,
                     max_blocks=64, token_budget=64)
    sched.cfg.chunk_tokens = 10
    sched.cfg.fill_to_bucket = True
    sched.add(req(0, plen=1, max_new=4))
    d = sched.schedule()
    advance(sched, d)                          # lane 0 now decodes
    sched.add(req(1, plen=100, max_new=1))
    d = sched.schedule()
    # decode(1) + chunk(10) = 11 -> bucket 16: the chunk grows to 15
    assert d.num_scheduled[0] == 1
    assert d.num_scheduled[1] == 15
    assert sum(d.num_scheduled.values()) == 16
    assert kv.n_tokens(1) == 15                # fills got KV slots too


def test_bucket_fill_never_exceeds_feed_or_budget():
    sched, kv = make(n_lanes=2, num_blocks=65, block_size=2, max_blocks=16,
                     token_budget=16)
    sched.cfg.chunk_tokens = 2
    sched.cfg.fill_to_bucket = True
    sched.add(req(0, plen=1, max_new=2))
    d = sched.schedule()
    advance(sched, d)                          # lane 0 now decodes
    sched.add(req(1, plen=4, max_new=1))
    d = sched.schedule()
    # decode(1) + chunk(2) = 3 -> bucket 4: ONE fill token rides; the
    # chunk never grows past the remaining feed
    assert d.num_scheduled[0] == 1
    assert d.num_scheduled[1] == 3
    r1 = next(r for r in d.scheduled if r.request_id == 1)
    assert r1.cursor + d.num_scheduled[1] <= len(r1.feed)
    assert sum(d.num_scheduled.values()) <= 16     # budget still binds


# ---------------------------------------------------------------------------
# segment-tile metadata (TileMap) invariants over scheduled batches
# ---------------------------------------------------------------------------
from repro.serving import RaggedBatch  # noqa: E402
from repro.serving.batch import (TILE_HI, TILE_LANE, TILE_LO,  # noqa: E402
                                 TILE_POS0, TILE_WINDOW)


def _schedule_batch(sched, kv, n_lanes, tile):
    d = sched.schedule()
    batch = RaggedBatch.build(d, kv, n_lanes, kv.block_size,
                              cap=sched._budget())
    return d, batch, batch.tiles(n_lanes, tile)


def advance_chunked(sched, decision):
    """Consume every scheduled token (the chunk-aware engine stand-in)."""
    for r in list(decision.scheduled):
        n = decision.num_scheduled[r.request_id]
        if r.cursor + n == len(r.feed):
            r.generated.append(0)
            r.feed.append(0)
        r.cursor += n
        if len(r.generated) >= r.max_new_tokens:
            sched.finish(r)


def test_cu_seqlens_partition_flat_stream_exactly():
    """cu_seqlens must be the exact segment boundaries of the flat stream:
    starting at 0, ending at total_tokens, one interval per scheduled
    request matching its (q_start, seg_len)."""
    sched, kv = make(n_lanes=3, num_blocks=65, block_size=2, max_blocks=16,
                     token_budget=16)
    sched.cfg.chunk_tokens = 5
    sched.cfg.fill_to_bucket = True
    for i in range(3):
        sched.add(req(i, plen=4 + 3 * i, max_new=2))
    for _ in range(6):
        if not sched.has_work():
            break
        d, batch, tm = _schedule_batch(sched, kv, 3, tile=4)
        total = sum(d.num_scheduled.values())
        assert tm.cu_seqlens[0] == 0 and tm.cu_seqlens[-1] == total
        bounds = set(zip(tm.cu_seqlens[:-1].tolist(),
                         tm.cu_seqlens[1:].tolist()))
        for r in d.scheduled:
            off = batch.q_starts[r.request_id]
            assert (off, off + batch.seg_lens[r.request_id]) in bounds
        assert len(bounds) == len(d.scheduled)
        advance_chunked(sched, d)


def test_tile_map_covers_every_scheduled_token_once():
    """Across a full mixed drain, the tile map must partition the real
    rows: disjoint [lo, hi) slabs inside one window and one segment whose
    union is every scheduled token, with per-tile lane/pos agreeing with
    the per-token arrays."""
    tile = 4
    sched, kv = make(n_lanes=3, num_blocks=129, block_size=2, max_blocks=32,
                     token_budget=13)
    sched.cfg.chunk_tokens = 6
    sched.cfg.fill_to_bucket = True
    for i in range(5):
        sched.add(req(i, plen=2 + 7 * (i % 3), max_new=3))
    for _ in range(100):
        if not sched.has_work():
            break
        d, batch, tm = _schedule_batch(sched, kv, 3, tile)
        total = batch.total_tokens
        covered = np.zeros(max(total, 1), bool)
        for t in range(tm.n_tiles):
            lo, hi = int(tm.meta[TILE_LO, t]), int(tm.meta[TILE_HI, t])
            assert lo < hi
            assert lo // tile == (hi - 1) // tile          # one q window
            assert tm.meta[TILE_WINDOW, t] == lo // tile
            assert not covered[lo:hi].any()                # disjoint
            covered[lo:hi] = True
            assert np.all(tm.row_tile[lo:hi] == t)
            assert np.all(batch.token_lane[lo:hi]
                          == tm.meta[TILE_LANE, t])
            assert np.all(batch.token_pos[lo:hi]
                          == tm.meta[TILE_POS0, t] + np.arange(hi - lo))
        assert covered.all() or total == 0                 # full coverage
        # static capacity: windows + lanes, never exceeded
        assert tm.meta.shape[1] == -(-batch.padded_tokens // tile) + 3
        assert tm.n_tiles <= tm.meta.shape[1]
        advance_chunked(sched, d)
    assert not sched.has_work()


def test_fill_to_bucket_padding_becomes_real_prefill_under_tiling():
    """The flat bucket's padding slots must still be converted to real
    prefill work when tiling is on, and the tile map must cover the filled
    chunk: a decode + a long prefill land on the pow2 boundary with
    padding_efficiency 1.0."""
    kv = KVCacheManager(600, 2, max_blocks_per_seq=300)
    sched = Scheduler(SchedulerConfig(n_lanes=2, token_budget=256,
                                      chunk_tokens=255,
                                      fill_to_bucket=True), kv)
    r0 = req(0, plen=1, max_new=4)
    sched.add(r0)
    d = sched.schedule()
    advance(sched, d)                          # r0 emitted: now decoding
    sched.add(req(1, plen=400, max_new=1))
    d, batch, tm = _schedule_batch(sched, kv, 2, tile=16)
    assert batch.total_tokens == 256 == batch.padded_tokens
    assert batch.padding_efficiency == 1.0
    # decode segment [0,1) splits window 0; prefill fills the rest:
    # 16 windows + 1 boundary split = 17 tiles, all real
    assert tm.n_tiles == 17
    real = tm.meta[:, :tm.n_tiles]
    assert (real[TILE_HI] - real[TILE_LO]).sum() == 256
    assert np.array_equal(tm.cu_seqlens, np.asarray([0, 1, 256]))


# ---------------------------------------------------------------------------
# speculative draft scheduling (budget / flat-slot / fill interactions)
# ---------------------------------------------------------------------------
from repro.serving import Proposer  # noqa: E402


class _FixedProposer(Proposer):
    """Deterministic test proposer: always offers the same draft tokens."""

    def __init__(self, drafts):
        self.drafts = list(drafts)

    def propose(self, tokens, k):
        return self.drafts[:k]


def make_spec(n_lanes=2, num_blocks=65, block_size=2, max_blocks=16,
              token_budget=0, draft_k=4, drafts=(7, 8, 9, 7, 8, 9)):
    kv = KVCacheManager(num_blocks, block_size,
                        max_blocks_per_seq=max_blocks)
    sched = Scheduler(SchedulerConfig(n_lanes=n_lanes,
                                      token_budget=token_budget,
                                      chunk_tokens=8,
                                      draft_k=draft_k,
                                      proposer=_FixedProposer(drafts)), kv)
    return sched, kv


def advance_spec(sched, kv, decision):
    """Engine stand-in under speculation: consume the fed tokens, accept
    no drafts (emit only the bonus token), and rewind the rejected draft
    slots — the contract the real engine honors after verification."""
    for r in decision.scheduled:
        n = decision.num_scheduled[r.request_id]
        k = len(decision.drafts.get(r.request_id, []))
        if r.cursor + (n - k) == len(r.feed):
            r.generated.append(0)
            r.feed.append(0)
        r.cursor += n - k
        if kv.has_seq(r.request_id):
            kv.rewind(r.request_id, r.cursor)


def to_decode(sched, kv, rid=0, plen=1):
    """Admit a request and advance it to its first decode step."""
    r = req(rid, plen=plen, max_new=8)
    sched.add(r)
    while not r.is_decode or r.lane is None:
        d = sched.schedule()
        advance_spec(sched, kv, d)
    return r


def test_decode_lane_with_drafts_occupies_1_plus_k_flat_slots():
    """A speculating decode lane schedules (and KV-reserves) 1 + k tokens
    and its flat segment carries the feed token followed by the drafts."""
    sched, kv = make_spec(draft_k=3)
    r = to_decode(sched, kv)
    d = sched.schedule()
    assert d.num_scheduled[0] == 4                 # 1 feed + 3 drafts
    assert d.drafts[0] == [7, 8, 9]
    assert d.n_decode_tokens == 4 and d.n_draft_tokens == 3
    assert kv.n_tokens(0) == r.cursor + 4          # every draft has a slot
    batch = RaggedBatch.build(d, kv, 2, 2, cap=sched._budget())
    assert batch.seg_lens[0] == 4
    assert batch.seg_drafts[0] == 3
    assert batch.n_draft_tokens == 3
    seg = batch.tokens[batch.q_starts[0]:batch.q_starts[0] + 4].tolist()
    assert seg == [r.feed[r.cursor]] + [7, 8, 9]
    # consecutive positions: verification rows are ordinary chunk rows
    pos = batch.token_pos[batch.q_starts[0]:batch.q_starts[0] + 4]
    assert pos.tolist() == list(range(r.cursor, r.cursor + 4))


def test_rejected_drafts_charge_budget_not_progress():
    """Drafted-but-rejected tokens consume the step's token budget (and
    KV slots) but the request's progress only advances by what the engine
    accepts — after a full rejection + rewind the next step re-schedules
    from the same cursor."""
    sched, kv = make_spec(n_lanes=2, token_budget=6, draft_k=4)
    r = to_decode(sched, kv)
    cursor0 = r.cursor
    d = sched.schedule()
    assert d.num_scheduled[0] == 5                 # 1 + 4 drafts
    assert sum(d.num_scheduled.values()) <= 6      # budget includes drafts
    assert kv.n_tokens(0) == cursor0 + 5
    # engine verdict: all drafts rejected -> emit 1 bonus token, rewind
    r.generated.append(0)
    r.feed.append(0)
    r.cursor = cursor0 + 1
    kv.rewind(0, r.cursor)
    assert kv.n_tokens(0) == cursor0 + 1
    d = sched.schedule()                           # same point, drafts again
    assert d.num_scheduled[0] == 5
    assert kv.n_tokens(0) == cursor0 + 2 + 4


def test_draft_budget_is_fair_across_decode_lanes():
    """A greedy 1+k draft segment must never starve a sibling decode lane
    out of the step: with budget 6 and 3 decode lanes, every lane decodes
    and the draft budget shrinks to what is left."""
    sched, kv = make_spec(n_lanes=3, token_budget=6, draft_k=8)
    for rid in range(3):                           # admitted in one step
        sched.add(req(rid, plen=1, max_new=50))
    d = sched.schedule()
    assert d.n_admitted == 3
    advance_spec(sched, kv, d)                     # all three now decode
    d = sched.schedule()
    assert len(d.scheduled) == 3
    assert all(d.num_scheduled[rid] >= 1 for rid in range(3))
    assert sum(d.num_scheduled.values()) <= 6
    # lane order: the first decode lane gets the spare draft budget
    assert d.num_scheduled[0] == 4                 # 6 - 2 reserved siblings
    assert d.num_scheduled[1] == 1 and d.num_scheduled[2] == 1


def test_drafts_capped_by_remaining_output():
    """A request one token from max_new_tokens proposes no drafts (the
    bonus token already finishes it); nearly-done requests cap k."""
    sched, kv = make_spec(draft_k=4)
    r = to_decode(sched, kv)
    r.max_new_tokens = len(r.generated) + 1        # exactly one to go
    d = sched.schedule()
    assert d.num_scheduled[0] == 1 and 0 not in d.drafts
    advance_spec(sched, kv, d)
    r.max_new_tokens = len(r.generated) + 3        # room for 2 drafts
    d = sched.schedule()
    assert d.num_scheduled[0] == 3 and d.drafts[0] == [7, 8]


def test_draft_budget_reserves_prefill_and_admission_floor():
    """Drafts must never starve the rest of the system: a running
    prefill lane keeps its one-token-per-step progress floor, and a
    waiting request with a free lane still gets admitted — even when a
    decode lane could drink the whole budget as drafts."""
    sched, kv = make_spec(n_lanes=2, token_budget=8, draft_k=8)
    r = req(0, plen=1, max_new=50)
    sched.add(r)
    d = sched.schedule()
    advance_spec(sched, kv, d)                     # lane 0 now decodes
    sched.add(req(1, plen=30, max_new=1))
    d = sched.schedule()
    # one budget token was reserved for the pending admission
    assert d.n_admitted == 1
    assert d.num_scheduled[0] == 7                 # 1 + (8 - 1 - reserve)
    assert d.num_scheduled[1] >= 1
    advance_spec(sched, kv, d)
    d = sched.schedule()
    # req 1 is now a RUNNING prefill lane: same floor, every step
    assert d.num_scheduled[0] == 7
    assert d.num_scheduled[1] >= 1
    assert sum(d.num_scheduled.values()) <= 8


def test_fill_to_bucket_tops_up_with_prefill_not_drafts():
    """Bucket fill under speculation: the pow2 remainder is carried by
    extending a PREFILL chunk; the draft segment itself never grows past
    1 + draft_k."""
    kv = KVCacheManager(129, 2, max_blocks_per_seq=64)
    sched = Scheduler(SchedulerConfig(n_lanes=2, token_budget=64,
                                      chunk_tokens=10, fill_to_bucket=True,
                                      draft_k=2,
                                      proposer=_FixedProposer([7, 8])), kv)
    r = to_decode(sched, kv)
    sched.add(req(1, plen=100, max_new=1))
    d = sched.schedule()
    # decode(1 + 2 drafts) + chunk(10) = 13 -> bucket 16: the prefill
    # chunk grows by 3, the draft segment stays at 3
    assert d.num_scheduled[0] == 3 and d.drafts[0] == [7, 8]
    assert d.num_scheduled[1] == 13
    assert sum(d.num_scheduled.values()) == 16


@pytest.mark.parametrize("num_blocks,want_k", [(4, 1), (5, 3), (6, 4)])
def test_draft_tail_truncation_at_pool_keeps_segment_shape(num_blocks,
                                                           want_k):
    """Pinned: when the dry pool truncates a speculating decode lane's
    1+k segment, the cut always lands inside the DRAFT tail — the feed
    token survives, the drafts list shrinks to exactly the scheduled
    remainder, and every surviving token holds a KV slot."""
    sched, kv = make_spec(n_lanes=1, num_blocks=num_blocks, block_size=2,
                          max_blocks=8, draft_k=4)
    r = to_decode(sched, kv, plen=4)
    d = sched.schedule()
    n = d.num_scheduled[0]
    k = len(d.drafts.get(0, []))
    assert n == 1 + k                       # the feed token always rides
    assert k == want_k
    assert d.drafts[0] == [7, 8, 9, 7][:k]
    assert d.n_draft_tokens == k
    assert kv.n_tokens(0) == r.cursor + n   # slots match the truncation
    assert d.n_preempted == 0 and r.lane is not None


def test_draft_tail_truncation_at_budget_keeps_feed_token():
    """Pinned: the token budget truncates the draft tail the same way the
    pool does — mid-draft, never into the feed token."""
    sched, kv = make_spec(n_lanes=1, token_budget=3, draft_k=8)
    r = to_decode(sched, kv)
    d = sched.schedule()
    assert d.num_scheduled[0] == 3
    assert d.drafts[0] == [7, 8]
    assert d.n_draft_tokens == 2
    assert kv.n_tokens(0) == r.cursor + 3


def test_draft_tail_truncated_to_bare_feed_token():
    """Pinned: truncation all the way to the feed token degrades the lane
    to plain decode — no drafts entry at all, not an empty one."""
    sched, kv = make_spec(n_lanes=1, token_budget=1, draft_k=4)
    r = to_decode(sched, kv)
    d = sched.schedule()
    assert d.num_scheduled[0] == 1
    assert 0 not in d.drafts and d.n_draft_tokens == 0
    assert kv.n_tokens(0) == r.cursor + 1


def test_preempted_speculating_lane_drops_its_drafts():
    """When the pool dries up and the speculating decode lane itself is
    the victim's priority senior, draft slots are truncated before real
    tokens: a drafts-with-no-pool step degrades toward plain decode."""
    # pool: 3 usable blocks of 2; lane 0 decoding with 4 prompt tokens
    # (2 blocks) wants 1 feed + 3 drafts (room-capped), but the 3rd draft
    # would need a 4th block
    sched, kv = make_spec(n_lanes=1, num_blocks=4, block_size=2,
                          max_blocks=4, draft_k=4)
    r = to_decode(sched, kv, plen=4)
    d = sched.schedule()
    # the segment truncates mid-chunk at the dry pool: the feed token and
    # the first draft keep their slots, nobody is preempted
    assert d.num_scheduled[0] == 2
    assert d.drafts[0] == [7]
    assert kv.n_tokens(0) == r.cursor + 2
    assert d.n_preempted == 0 and r.lane is not None
