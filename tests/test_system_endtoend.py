"""End-to-end reproduction of the paper's evaluation logic (Table 1):
running the full distributed workflow with REAL local training and the
paper-calibrated remote model must show remote DCAI >> local turnaround."""
import jax
import numpy as np
import pytest

from repro.core import build_system, dnn_trainer_flow
from repro.core.transfer import FileRef


def _register_real_braggnn_training(sys_, steps=8):
    """A real (tiny) BraggNN training function, runnable on any endpoint."""
    import jax.numpy as jnp
    from repro.configs import BraggNNConfig
    from repro.data.synthetic import bragg_patches
    from repro.models import braggnn
    from repro.optim import adam

    def train_braggnn():
        cfg = BraggNNConfig()
        key = jax.random.PRNGKey(0)
        params = braggnn.init_params(key, cfg)
        opt = adam(1e-3)
        state = opt.init(params)

        @jax.jit
        def step(p, s, batch):
            (l, _), g = jax.value_and_grad(
                lambda p_: braggnn.loss_fn(p_, batch, cfg),
                has_aux=True)(p)
            p2, s2 = opt.update(g, s, p)
            return p2, s2, l

        for i in range(steps):
            d = bragg_patches(jax.random.fold_in(key, i), 32)
            params, state, loss = step(
                params, state, {"patches": d["patches"],
                                "centers": d["centers"]})
        sys_.store.put("alcf", FileRef("braggnn.npz", 3_000_000,
                                       payload=params))
        return {"final_loss": float(loss)}

    return sys_.funcx.register_function(train_braggnn)


@pytest.mark.slow
def test_remote_dcai_beats_local_turnaround():
    # --- remote scenario: workflow over WAN to the DCAI system ------------
    remote = build_system()
    tok = remote.user_token()
    for i in range(10):
        remote.store.put("slac", FileRef(f"d{i}.h5", 50_000_000))
    fid = _register_real_braggnn_training(remote)
    # Cerebras endpoint: modeled with the paper's measured 19 s
    eid = remote.funcx.register_endpoint("cerebras", mode="modeled")
    flow = remote.flows.deploy(dnn_trainer_flow())
    run = remote.flows.run(flow, {
        "src": "slac", "dc": "alcf",
        "dataset": [f"d{i}.h5" for i in range(10)],
        "train_endpoint": eid, "train_function": fid,
        "train_args": [], "train_kwargs": {}, "modeled_duration": 19.0,
        "model_artifacts": ["braggnn.npz"], "model_name": "braggnn.npz",
        "register_as": "braggnn", "version_tag": "exp-001", "metrics": {},
    }, tok)
    assert run.status == "SUCCEEDED"
    remote_turnaround = run.turnaround

    # --- local scenario: same training on the local V100 (paper: 1102 s) --
    local = build_system()
    local_fid = _register_real_braggnn_training(local)
    local_eid = local.funcx.register_endpoint("local-v100", mode="modeled")
    tr = local.funcx.run(local_eid, local_fid, modeled_duration=1102.0)
    local_turnaround = tr.duration + tr.overhead

    # the paper's headline claim: remote is > 30x faster despite WAN costs
    assert remote_turnaround < local_turnaround / 30.0
    # and WAN+service overhead is a real, visible share of remote end-to-end
    br = remote.clock.breakdown()
    assert br["sim"] > 1.0
    assert br["modeled"] == pytest.approx(19.0)
    # the trained model really exists at the edge with real trained weights
    entry = remote.repo.latest("braggnn")
    assert entry.artifact.payload is not None


def test_model_repository_foundation_selection():
    """Future-work #1: best_foundation picks the best prior version."""
    sys_ = build_system()
    for i, vl in enumerate([0.5, 0.2, 0.3]):
        sys_.store.put("slac", FileRef(f"m{i}", 1000))
        sys_.repo.register("net", f"v{i}",
                           sys_.store.get("slac", f"m{i}"),
                           metrics={"val_loss": vl})
    best = sys_.repo.best_foundation("net", "val_loss")
    assert best.version == 2
    assert best.metrics["val_loss"] == 0.2
