"""Analytical cost model (paper §4): Eq. (1)-(3), Fig. 4 crossover."""
import pytest

from repro.core import build_system


@pytest.fixture
def cm():
    return build_system().costmodel


def test_eq1_components(cm):
    n = 800_000
    c = cm.f_conventional_dc(n)
    # paper: A_dc = 2.44 us/peak on the 1024-core cluster
    assert c.breakdown["analyze"] == pytest.approx(n * 2.44e-6)
    assert c.breakdown["data_up"] > 0
    assert c.total == pytest.approx(sum(c.breakdown.values()))


def test_eq3_static_train_cost_dominates_small_n(cm):
    """For small N the 19 s Cerebras train dominates f_ml."""
    c = cm.f_ml(10_000, p=0.1)
    assert c.breakdown["train"] == pytest.approx(19.0)
    assert c.breakdown["train"] / c.total > 0.5


def test_crossover_exists_and_orders_strategies(cm):
    """Fig. 4: conventional wins for small N, ML surrogate for large N."""
    n_star = cm.crossover(p=0.1)
    assert n_star is not None
    small = max(1, n_star // 10)
    large = n_star * 10
    assert cm.f_conventional_dc(small).total < cm.f_ml(small).total
    assert cm.f_ml(large).total < cm.f_conventional_dc(large).total
    # crossover in a physically sensible range (Fig. 4 shows ~1e6-1e8 peaks)
    assert 10_000 < n_star < 10**9


def test_advise(cm):
    n_star = cm.crossover(p=0.1)
    assert cm.advise(max(1, n_star // 10)) != "ml_surrogate"
    assert cm.advise(n_star * 10) == "ml_surrogate"


def test_per_datum_costs_converge_to_estimate_cost(cm):
    """As N -> inf, ML per-datum cost -> E + transfer overhead share."""
    per = cm.f_ml(10**9, p=0.1).per_datum(10**9)
    # E = 0.35us; with p=0.1 upload+label adds ~(0.24+2.44)*0.1 us
    assert per < 1.5e-6
