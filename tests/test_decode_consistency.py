"""Decode-vs-teacher-forced consistency: for every family, feeding tokens
one-by-one through ``decode_step`` must reproduce the forward pass's logits.
This cross-validates the two execution paths (chunked/parallel train form vs
recurrent/cached decode form) — the strongest correctness property the
system has, and it covers the SSD scan, mLSTM chunkwise form, sLSTM scan,
rolling KV caches, and zamba2's shared-attention caches at once."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

# one representative per family (keep runtime sane); fp32 compute
FAMILIES = [
    "gemma-7b",              # dense (tied embeddings, geglu)
    "starcoder2-7b",         # dense (SWA, layernorm+bias, non-gated)
    "deepseek-moe-16b",      # moe (shared experts, first dense layer)
    "xlstm-1.3b",            # ssm (mLSTM + sLSTM)
    "zamba2-2.7b",           # hybrid (mamba2 + shared attn)
    "whisper-base",          # enc-dec
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(key, arch):
    cfg = get_config(arch).smoke_variant()
    if cfg.moe is not None:
        # capacity drops are a train-time batching artifact; give the router
        # enough capacity that forward and per-token decode see identical
        # expert assignments (drop-free regime)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = build_model(cfg)
    params = api.init(key)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)

    kwargs = {}
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.encoder_positions, cfg.frontend.d_embed))
        kwargs["frames"] = frames

    fwd_logits, _ = api.forward(params, tokens,
                                compute_dtype=jnp.float32, remat=False,
                                **kwargs)

    cache = api.init_cache(B, S, dtype=jnp.float32)
    if cfg.family == "audio":
        # production prefill computes cross-attn K/V from the encoder once
        from repro.models import encdec
        enc = encdec.encode(params, frames, cfg,
                            compute_dtype=jnp.float32)
        cache["cross"] = encdec.encoder_kv(params, enc, cfg)

    dec_logits = []
    for t in range(S):
        logits, cache = api.decode_step(params, cache, tokens[:, t:t + 1],
                                        compute_dtype=jnp.float32)
        dec_logits.append(logits[:, 0])
    dec = jnp.stack(dec_logits, axis=1)

    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd_logits),
                               atol=2e-2, rtol=2e-2)


def test_rolling_cache_matches_windowed_forward(key):
    """Sliding-window decode with a rolling buffer == windowed forward."""
    cfg = get_config("starcoder2-7b").smoke_variant()
    cfg = dataclasses.replace(cfg, sliding_window=8)
    api = build_model(cfg)
    params = api.init(key)
    B, S, W = 1, 24, 8
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    fwd_logits, _ = api.forward(params, tokens, window=W,
                                compute_dtype=jnp.float32, remat=False)
    cache = api.init_cache(B, S, window=W, dtype=jnp.float32)
    assert cache["scan"]["k"].shape[2] == W   # rolling buffer, not S slots
    outs = []
    for t in range(S):
        logits, cache = api.decode_step(params, cache, tokens[:, t:t + 1],
                                        window=W,
                                        compute_dtype=jnp.float32)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd_logits),
                               atol=2e-2, rtol=2e-2)
