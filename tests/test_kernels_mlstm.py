"""mLSTM chunkwise Pallas kernel vs the model's chunkwise form AND the
sequential decode recurrence (triple cross-validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models.xlstm import mlstm_chunkwise, mlstm_decode_step


def _mk(key, B, L, H, hd):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, L, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, L, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, L, H, hd)) * 0.5
    log_i = jax.random.normal(ks[3], (B, L, H)) * 0.5
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, L, H)) + 2.0)
    return q, k, v, log_i, log_f


SWEEP = [
    (1, 64, 1, 16, 16),
    (2, 128, 2, 32, 32),
    (2, 128, 4, 64, 64),
    (1, 96, 2, 24, 32),     # non-pow2 dims
]


@pytest.mark.parametrize("B,L,H,hd,chunk", SWEEP)
def test_kernel_vs_model_chunkwise(key, B, L, H, hd, chunk):
    q, k, v, li, lf = _mk(key, B, L, H, hd)
    h_k = ops.mlstm_scan_heads(q, k, v, li, lf, chunk=chunk, interpret=True)
    h_m, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               atol=2e-4, rtol=2e-3)


def test_kernel_vs_sequential_recurrence(key):
    B, L, H, hd = 1, 32, 2, 16
    q, k, v, li, lf = _mk(key, B, L, H, hd)
    h_k = ops.mlstm_scan_heads(q, k, v, li, lf, chunk=8, interpret=True)

    state = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
             jnp.full((B, H), -1e30))
    outs = []
    for t in range(L):
        state, h_t = mlstm_decode_step(state, q[:, t], k[:, t], v[:, t],
                                       li[:, t], lf[:, t])
        outs.append(h_t)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_seq),
                               atol=2e-4, rtol=2e-3)


def test_chunk_invariance(key):
    q, k, v, li, lf = _mk(key, 1, 64, 2, 16)
    h1 = ops.mlstm_scan_heads(q, k, v, li, lf, chunk=8, interpret=True)
    h2 = ops.mlstm_scan_heads(q, k, v, li, lf, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-4, rtol=2e-3)
