"""SSD-scan Pallas kernel vs sequential-recurrence oracle + model path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import ssd_reference
from repro.kernels.ssm_scan import ssd_scan


def _mk(key, B, L, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    return x, dt, A, Bm, Cm


SWEEP = [
    # B, L, H, P, G, N, chunk
    (1, 64, 1, 16, 1, 8, 16),
    (2, 128, 4, 32, 1, 16, 32),
    (2, 128, 4, 32, 2, 16, 64),    # grouped B/C
    (1, 256, 8, 16, 4, 32, 128),
    (1, 96, 2, 24, 2, 8, 32),      # non-pow2 dims
]


@pytest.mark.parametrize("B,L,H,P,G,N,chunk", SWEEP)
def test_ssd_kernel_vs_sequential(key, B, L, H, P, G, N, chunk):
    x, dt, A, Bm, Cm = _mk(key, B, L, H, P, G, N)
    y_k = ops.ssd_scan_heads(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    xdt = jnp.transpose(x * dt[..., None], (0, 2, 1, 3))
    dA = jnp.transpose(dt * A[None, None, :], (0, 2, 1))
    y_ref = ssd_reference(xdt, dA, jnp.transpose(Bm, (0, 2, 1, 3)),
                          jnp.transpose(Cm, (0, 2, 1, 3)))
    y_ref = jnp.transpose(y_ref, (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-4)


def test_model_chunked_matches_kernel(key):
    """models/ssm.py::ssd_chunked (XLA path) == Pallas kernel."""
    from repro.models.ssm import ssd_chunked
    x, dt, A, Bm, Cm = _mk(key, 2, 128, 4, 32, 1, 16)
    y_m, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y_k = ops.ssd_scan_heads(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k),
                               atol=5e-5, rtol=5e-4)


def test_chunk_invariance(key):
    """Result must not depend on the chunking."""
    x, dt, A, Bm, Cm = _mk(key, 1, 128, 2, 16, 1, 8)
    y1 = ops.ssd_scan_heads(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    y2 = ops.ssd_scan_heads(x, dt, A, Bm, Cm, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-5, rtol=5e-4)


def test_decode_step_matches_scan(key):
    """Recurrent decode step == last position of the chunked scan."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    B, L, H, P, G, N = 2, 32, 2, 16, 1, 8
    x, dt, A, Bm, Cm = _mk(key, B, L, H, P, G, N)
    y_scan, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)

    state = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        state, y_t = ssd_decode_step(
            state, x[:, t].astype(jnp.float32) if False else x[:, t],
            dt[:, t], A, Bm[:, t], Cm[:, t])
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_scan[:, -1]),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final),
                               atol=1e-4, rtol=1e-3)
