"""Block allocator + KV cache manager invariants (alloc/free/refcount)."""
import pytest

from repro.serving import BlockAllocator, KVCacheManager, NULL_BLOCK


def test_allocator_free_list_roundtrip():
    a = BlockAllocator(8)                    # 7 usable (block 0 reserved)
    assert a.num_free == 7
    blocks = [a.allocate() for _ in range(7)]
    assert sorted(blocks) == list(range(1, 8))
    assert NULL_BLOCK not in blocks
    assert a.num_free == 0
    with pytest.raises(RuntimeError):
        a.allocate()
    for b in blocks:
        a.decref(b)
    assert a.num_free == 7
    assert a.num_allocated == 0


def test_allocator_refcounts():
    a = BlockAllocator(4)
    b = a.allocate()
    a.incref(b)
    assert a.refcount(b) == 2
    a.decref(b)
    assert a.refcount(b) == 1
    assert a.num_free == 2                   # not yet returned
    a.decref(b)
    assert a.refcount(b) == 0
    assert a.num_free == 3
    with pytest.raises(KeyError):
        a.decref(b)                          # double free
    with pytest.raises(KeyError):
        a.incref(b)                          # incref of unallocated


def test_manager_append_allocates_on_block_boundary():
    m = KVCacheManager(num_blocks=16, block_size=4, max_blocks_per_seq=4)
    m.allocate(0, 0)
    new_blocks = [m.append_token(0) for _ in range(10)]
    # a new physical block exactly every block_size tokens
    got = [b is not None for b in new_blocks]
    assert got == [True, False, False, False] * 2 + [True, False]
    assert m.n_tokens(0) == 10
    assert len(m.block_table(0)) == 3
    m.free(0)
    assert m.num_free_blocks == 15
    assert not m.has_seq(0)


def test_manager_padded_table_null_fills():
    m = KVCacheManager(num_blocks=8, block_size=2, max_blocks_per_seq=4)
    m.allocate(7, 3)                         # 2 blocks for 3 tokens
    row = m.padded_table(7)
    assert row.shape == (4,)
    assert (row[:2] > 0).all()
    assert (row[2:] == NULL_BLOCK).all()


def test_manager_fork_shares_blocks_refcounted():
    m = KVCacheManager(num_blocks=8, block_size=2, max_blocks_per_seq=4)
    m.allocate(0, 4)                         # block-aligned: 2 blocks
    free_before = m.num_free_blocks
    m.fork(0, 1)
    assert m.num_free_blocks == free_before  # no new physical blocks
    assert m.block_table(1) == m.block_table(0)
    m.free(0)
    assert m.num_free_blocks == free_before  # still referenced by seq 1
    m.free(1)
    assert m.num_free_blocks == free_before + 2


def test_manager_fork_requires_block_alignment():
    m = KVCacheManager(num_blocks=8, block_size=4, max_blocks_per_seq=4)
    m.allocate(0, 3)
    with pytest.raises(ValueError):
        m.fork(0, 1)


def test_manager_per_seq_ceiling():
    m = KVCacheManager(num_blocks=64, block_size=2, max_blocks_per_seq=2)
    m.allocate(0, 4)
    with pytest.raises(ValueError):
        m.append_token(0)                    # 5th token needs a 3rd block
    with pytest.raises(ValueError):
        m.can_allocate(5)


def test_manager_exhaustion_raises():
    m = KVCacheManager(num_blocks=3, block_size=2, max_blocks_per_seq=2)
    m.allocate(0, 2)
    m.allocate(1, 2)
    assert m.num_free_blocks == 0
    with pytest.raises(RuntimeError):
        m.allocate(2, 1)
    assert not m.can_allocate(1)
    assert m.utilization() == 1.0


def test_full_prefix_match_accounts_for_cow_block():
    """Regression: a prompt fully covered by cached blocks still needs one
    block to re-process its last token (CoW fork of the shared tail).
    can_admit must count it — and when even that block cannot be found,
    the admission plan degrades to recomputing the tail so a pool that
    could serve the prompt cache-off still serves it cache-on."""
    # roomy pool: full match + CoW fork both fit
    m = KVCacheManager(8, 4, max_blocks_per_seq=4, enable_prefix_cache=True)
    feed = list(range(4))
    m.begin_seq(0, feed)
    for t in feed[m.n_tokens(0):]:
        m.append_token(0, t)
    m.free(0)                                # block now cached (evictable)
    assert m.can_admit(feed)
    assert m.begin_seq(1, feed) == 3         # capped at len(feed) - 1
    m.append_token(1, feed[3])               # CoW fork of the shared tail
    assert m.cow_copies == 1
    assert len(m.take_copy_ops()) == 1
    m.free(1)

    # pathological pool: ONE usable block, fully cached by the match —
    # no CoW block exists, so the plan must drop the match and recompute
    t = KVCacheManager(2, 4, max_blocks_per_seq=1, enable_prefix_cache=True)
    t.begin_seq(0, feed)
    for tok in feed[t.n_tokens(0):]:
        t.append_token(0, tok)
    t.free(0)
    assert t.can_admit(feed)                 # serviceable by evicting
    assert t.begin_seq(1, feed) == 0         # degraded: prefill from scratch
    for tok in feed:
        t.append_token(1, tok)               # evicts the cached block
    assert t.n_tokens(1) == 4
    assert t.evictions == 1
    t.free(1)
