"""Block allocator + KV cache manager invariants (alloc/free/refcount),
including the speculative-decode rewind: after any propose/verify/rewind
sequence the pool must look exactly as if only the accepted tokens had
ever been appended — no orphaned or double-freed blocks, no stale prefix
cache entries, CoW-shared blocks never rewound in place."""
import pytest

from _hyp import given, settings, st

from repro.serving import BlockAllocator, KVCacheManager, NULL_BLOCK


def test_allocator_free_list_roundtrip():
    a = BlockAllocator(8)                    # 7 usable (block 0 reserved)
    assert a.num_free == 7
    blocks = [a.allocate() for _ in range(7)]
    assert sorted(blocks) == list(range(1, 8))
    assert NULL_BLOCK not in blocks
    assert a.num_free == 0
    with pytest.raises(RuntimeError):
        a.allocate()
    for b in blocks:
        a.decref(b)
    assert a.num_free == 7
    assert a.num_allocated == 0


def test_allocator_refcounts():
    a = BlockAllocator(4)
    b = a.allocate()
    a.incref(b)
    assert a.refcount(b) == 2
    a.decref(b)
    assert a.refcount(b) == 1
    assert a.num_free == 2                   # not yet returned
    a.decref(b)
    assert a.refcount(b) == 0
    assert a.num_free == 3
    with pytest.raises(KeyError):
        a.decref(b)                          # double free
    with pytest.raises(KeyError):
        a.incref(b)                          # incref of unallocated


def test_manager_append_allocates_on_block_boundary():
    m = KVCacheManager(num_blocks=16, block_size=4, max_blocks_per_seq=4)
    m.allocate(0, 0)
    new_blocks = [m.append_token(0) for _ in range(10)]
    # a new physical block exactly every block_size tokens
    got = [b is not None for b in new_blocks]
    assert got == [True, False, False, False] * 2 + [True, False]
    assert m.n_tokens(0) == 10
    assert len(m.block_table(0)) == 3
    m.free(0)
    assert m.num_free_blocks == 15
    assert not m.has_seq(0)


def test_manager_padded_table_null_fills():
    m = KVCacheManager(num_blocks=8, block_size=2, max_blocks_per_seq=4)
    m.allocate(7, 3)                         # 2 blocks for 3 tokens
    row = m.padded_table(7)
    assert row.shape == (4,)
    assert (row[:2] > 0).all()
    assert (row[2:] == NULL_BLOCK).all()


def test_manager_fork_shares_blocks_refcounted():
    m = KVCacheManager(num_blocks=8, block_size=2, max_blocks_per_seq=4)
    m.allocate(0, 4)                         # block-aligned: 2 blocks
    free_before = m.num_free_blocks
    m.fork(0, 1)
    assert m.num_free_blocks == free_before  # no new physical blocks
    assert m.block_table(1) == m.block_table(0)
    m.free(0)
    assert m.num_free_blocks == free_before  # still referenced by seq 1
    m.free(1)
    assert m.num_free_blocks == free_before + 2


def test_manager_fork_requires_block_alignment():
    m = KVCacheManager(num_blocks=8, block_size=4, max_blocks_per_seq=4)
    m.allocate(0, 3)
    with pytest.raises(ValueError):
        m.fork(0, 1)


def test_manager_per_seq_ceiling():
    m = KVCacheManager(num_blocks=64, block_size=2, max_blocks_per_seq=2)
    m.allocate(0, 4)
    with pytest.raises(ValueError):
        m.append_token(0)                    # 5th token needs a 3rd block
    with pytest.raises(ValueError):
        m.can_allocate(5)


def test_manager_exhaustion_raises():
    m = KVCacheManager(num_blocks=3, block_size=2, max_blocks_per_seq=2)
    m.allocate(0, 2)
    m.allocate(1, 2)
    assert m.num_free_blocks == 0
    with pytest.raises(RuntimeError):
        m.allocate(2, 1)
    assert not m.can_allocate(1)
    assert m.utilization() == 1.0


def test_full_prefix_match_accounts_for_cow_block():
    """Regression: a prompt fully covered by cached blocks still needs one
    block to re-process its last token (CoW fork of the shared tail).
    can_admit must count it — and when even that block cannot be found,
    the admission plan degrades to recomputing the tail so a pool that
    could serve the prompt cache-off still serves it cache-on."""
    # roomy pool: full match + CoW fork both fit
    m = KVCacheManager(8, 4, max_blocks_per_seq=4, enable_prefix_cache=True)
    feed = list(range(4))
    m.begin_seq(0, feed)
    for t in feed[m.n_tokens(0):]:
        m.append_token(0, t)
    m.free(0)                                # block now cached (evictable)
    assert m.can_admit(feed)
    assert m.begin_seq(1, feed) == 3         # capped at len(feed) - 1
    m.append_token(1, feed[3])               # CoW fork of the shared tail
    assert m.cow_copies == 1
    assert len(m.take_copy_ops()) == 1
    m.free(1)

    # pathological pool: ONE usable block, fully cached by the match —
    # no CoW block exists, so the plan must drop the match and recompute
    t = KVCacheManager(2, 4, max_blocks_per_seq=1, enable_prefix_cache=True)
    t.begin_seq(0, feed)
    for tok in feed[t.n_tokens(0):]:
        t.append_token(0, tok)
    t.free(0)
    assert t.can_admit(feed)                 # serviceable by evicting
    assert t.begin_seq(1, feed) == 0         # degraded: prefill from scratch
    for tok in feed:
        t.append_token(1, tok)               # evicts the cached block
    assert t.n_tokens(1) == 4
    assert t.evictions == 1
    t.free(1)


def test_free_block_accounting_unified_and_plan_aware():
    """Regression for the free-count drift that made preemption lie:
    ``num_free_blocks``, the admission planner, and the raw allocator
    count must all answer through one eviction-aware helper.  A plan
    cached by ``can_admit`` shields its device-hit blocks — they are
    neither counted free nor reclaimable — until the plan is consumed,
    invalidated by a cache mutation, or explicitly dropped."""
    bs = 2
    m = KVCacheManager(8, bs, max_blocks_per_seq=4,
                       enable_prefix_cache=True)
    feed = [1, 2, 3, 4]
    m.begin_seq(0, feed)
    for t in feed[m.n_tokens(0):]:
        m.append_token(0, t)
    chain = list(m.block_table(0))
    m.free(0)                        # B1,B2 now cache-only, on the LRU
    m.begin_seq(1, [9, 8])           # one unrelated cold block X
    for t in [9, 8][m.n_tokens(1):]:
        m.append_token(1, t)
    m.free(1)
    assert len(m._lru) == 3
    # eviction-aware: every cache-only block counts as reclaimable, so
    # the scheduler and the planner see the same number
    assert m.num_free_blocks == 7
    assert m.free_blocks(planned=False) == 7
    assert m.allocator.num_free == 4          # the raw list is smaller
    m.allocate(2, 4 * bs)                     # drain the raw free list
    assert m.num_free_blocks == 3             # cache-only blocks remain
    # planning an admission that hits B1,B2 shields exactly those two
    # (with the raw list empty the planner cannot take its fast path)
    assert m.can_admit(feed)
    assert m.num_free_blocks == 1
    assert m.free_blocks(planned=False) == 3  # raw view stays plan-blind
    m.drop_plan_protection()
    assert m.num_free_blocks == 3             # shield released on demand
    assert m.can_admit(feed)                  # re-arm the plan
    m.allocate(3, bs)                         # forces one eviction
    assert m.evictions == 1
    assert m.lookup_prefix(feed) == 4         # planned hits survived
    assert m.lookup_prefix([9, 8]) == 0       # the cold block was taken
    # the surviving plan is still consumable: the admission attaches the
    # protected chain instead of recomputing it
    m.free(2)
    assert m.begin_seq(4, feed) == 3          # full match, tail recompute
    assert m.block_table(4)[:2] == chain
    m.append_token(4, feed[3])                # CoW fork of the shared tail
    assert m.cow_copies == 1
    for sid in (3, 4):
        m.free(sid)
    m.take_copy_ops()
    assert m.num_free_blocks == 7             # accounting closed the loop
    assert m.allocator.num_allocated == len(m._lru)


# ---------------------------------------------------------------------------
# speculative-decode rewind
# ---------------------------------------------------------------------------
def _pool_state(m: KVCacheManager, seq_ids):
    """Content-addressed snapshot of everything rewind must keep honest:
    physical block ids differ between managers with different allocation
    histories, so compare counts, per-seq hash state, per-seq refcount
    shapes, and the digest set of the prefix cache."""
    return {
        "free": m.num_free_blocks,
        "allocated": m.allocator.num_allocated,
        "lru": len(m._lru),
        "cached": set(m._cached),
        "seqs": {
            sid: (m.n_tokens(sid), len(m.block_table(sid)),
                  tuple(m._seqs[sid].digests),
                  tuple(m._seqs[sid].pending or ()),
                  tuple(m.allocator.refcount(b)
                        for b in m.block_table(sid)))
            for sid in seq_ids if m.has_seq(sid)
        },
    }


def test_rewind_frees_draft_only_blocks():
    m = KVCacheManager(16, 4, max_blocks_per_seq=4)
    m.allocate(0, 0)
    for t in range(6):                       # 6 accepted tokens, 2 blocks
        m.append_token(0, t)
    free_before = m.num_free_blocks
    for t in range(5):                       # 5 drafts -> 11 tokens, 3 blocks
        m.append_token(0, 100 + t)
    assert m.num_free_blocks == free_before - 1
    m.rewind(0, 6)                           # all drafts rejected
    assert m.n_tokens(0) == 6
    assert len(m.block_table(0)) == 2
    assert m.num_free_blocks == free_before  # draft-only block came back
    with pytest.raises(ValueError):
        m.rewind(0, 7)                       # forward "rewind" is nonsense
    m.rewind(0, 6)                           # no-op rewind is fine
    m.free(0)
    assert m.num_free_blocks == 15           # no leak, no double free


def test_rewind_across_block_boundary_rehashes_cleanly():
    """Rejecting drafts that completed (and cache-registered) a full block
    must un-register it and rebuild the partial-block hash state, so
    re-appending the ACCEPTED continuation re-registers content-correct
    digests — the cache looks as if the drafts never happened."""
    bs = 4
    ref = KVCacheManager(16, bs, max_blocks_per_seq=4,
                         enable_prefix_cache=True)
    m = KVCacheManager(16, bs, max_blocks_per_seq=4,
                       enable_prefix_cache=True)
    feed = list(range(6))
    for mgr in (ref, m):
        mgr.begin_seq(0, feed)
        for t in feed[mgr.n_tokens(0):]:
            mgr.append_token(0, t)
    # m speculates 4 drafts (completing block 1 and starting block 2),
    # verification accepts 1 of them (token 50) + bonus
    for t in (50, 51, 52, 53):
        m.append_token(0, t)
    assert len(m._cached) == 2               # draft content got registered
    m.rewind(0, 7)
    # replay the accepted continuation on both managers
    for mgr in (ref, m):
        if mgr is ref:
            mgr.append_token(0, 50)
        for t in (60, 61, 62):
            mgr.append_token(0, t)
    assert _pool_state(m, [0]) == _pool_state(ref, [0])
    assert len(m._cached) == 2               # blocks 0 and 1, accepted content


def test_rewind_never_mutates_cow_shared_blocks():
    """A forked (refcount-shared) tail is never rewound in place: the
    rewinding side only drops its reference, and its next append
    copy-on-writes away from the still-shared block."""
    m = KVCacheManager(16, 2, max_blocks_per_seq=6)
    m.allocate(0, 0)
    for t in range(4):
        m.append_token(0, t)                 # 2 full blocks, aligned
    m.fork(0, 1)
    shared = m.block_table(0)
    assert m.block_table(1) == shared
    # seq 1 speculates into a fresh block, then rejects everything
    m.append_token(1, 10)
    m.append_token(1, 11)
    assert m.block_table(1)[:2] == shared    # shared prefix untouched
    m.rewind(1, 4)
    assert m.block_table(1) == shared
    assert [m.allocator.refcount(b) for b in shared] == [2, 2]
    # rewind INTO the shared region: only drops seq 1's references
    m.rewind(1, 2)
    assert m.block_table(1) == shared[:1]
    assert [m.allocator.refcount(b) for b in shared] == [2, 1]
    # seq 0 still owns its full table; writing on seq 1's side CoWs
    m.append_token(1, 99)
    assert m.block_table(1)[1] != shared[1]
    assert m.n_tokens(0) == 4 and m.block_table(0) == shared
    m.free(0)
    m.free(1)
    assert m.num_free_blocks == 15


def _drive_rewind_replay(seed, num_blocks, block_size, n_seqs, n_rounds):
    """The rollback invariant: a manager that speculates (appends drafts,
    then rewinds to the accepted watermark) must end every round in a
    state indistinguishable from a fresh manager replaying ONLY the
    accepted tokens — refcounts, free/LRU sizes, prefix-cache digests,
    per-seq hash state.  The replay mirrors the original admission/append
    split (same ``begin_seq`` feed, then the accepted continuation) so
    the two managers see identical non-speculative histories."""
    import numpy as np
    rng = np.random.default_rng(seed)
    mb = 6
    spec = KVCacheManager(num_blocks, block_size, max_blocks_per_seq=mb,
                          enable_prefix_cache=True)
    log = []          # ("admit", sid, feed) / ("extend", sid, toks) /
    #                   ("free", sid) — the accepted-only history
    for sid in range(n_seqs):
        plen = rng.integers(1, min(8, mb * block_size - 3))
        feed = [int(t) for t in rng.integers(0, 5, plen)]
        if not spec.can_admit(feed):
            continue
        start = spec.begin_seq(sid, feed)
        for t in feed[start:]:
            spec.append_token(sid, t)
        log.append(("admit", sid, feed))
        for _ in range(n_rounds):
            room = mb * block_size - spec.n_tokens(sid)
            k = int(rng.integers(0, min(4, room) + 1))
            if spec.allocator.num_free < k:
                k = 0     # keep draft appends off the eviction path: an
                #           eviction forced by a later-rejected draft is a
                #           real (and acceptable) spec-vs-replay divergence
            drafts = [int(t) for t in rng.integers(0, 5, k)]
            base = spec.n_tokens(sid)
            for t in drafts:
                spec.append_token(sid, t)
            m = int(rng.integers(0, len(drafts) + 1))     # accepted prefix
            spec.rewind(sid, base + m)
            if m:
                log.append(("extend", sid, drafts[:m]))
        if rng.random() < 0.3:
            spec.free(sid)
            log.append(("free", sid))
    spec.take_copy_ops()
    replay = KVCacheManager(num_blocks, block_size, max_blocks_per_seq=mb,
                            enable_prefix_cache=True)
    for op, sid, *rest in log:
        if op == "admit":
            start = replay.begin_seq(sid, rest[0])
            for t in rest[0][start:]:
                replay.append_token(sid, t)
        elif op == "extend":
            for t in rest[0]:
                replay.append_token(sid, t)
        else:
            replay.free(sid)
    replay.take_copy_ops()
    assert _pool_state(spec, range(n_seqs)) == \
        _pool_state(replay, range(n_seqs))


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    num_blocks=st.integers(8, 40),
    block_size=st.sampled_from([1, 2, 4]),
    n_seqs=st.integers(1, 5),
    n_rounds=st.integers(1, 5),
)
def test_fuzz_rewind_matches_accepted_only_replay(seed, num_blocks,
                                                  block_size, n_seqs,
                                                  n_rounds):
    """Hypothesis sweep of the rollback invariant (prefix sharing across
    sequences, partial accepts at every alignment, interleaved frees)."""
    _drive_rewind_replay(seed, num_blocks, block_size, n_seqs, n_rounds)


@pytest.mark.parametrize("seed", range(8))
def test_rewind_matches_accepted_only_replay_pinned(seed):
    """No-hypothesis slice of the rollback-replay fuzz (CI runs the full
    randomized sweep)."""
    _drive_rewind_replay(seed, num_blocks=10 + 4 * seed,
                         block_size=(1, 2, 4)[seed % 3],
                         n_seqs=1 + seed % 4, n_rounds=1 + seed % 5)
