import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# 4 virtual CPU devices so the mesh-sharded serving tests run in tier-1
# (single-device code is unaffected: unsharded arrays live on device 0).
# An explicit device-count flag in the environment wins — the dry-run
# forces 512 in its own process the same way.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests")
