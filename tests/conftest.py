import os

# keep tests on 1 device — the dry-run (and ONLY the dry-run) forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests")
