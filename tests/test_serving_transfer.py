"""KV-block wire format + disaggregated serving: the transfer test wall.

Three layers:

  * **Wire format** — pure-host tests over synthetic payloads: exact
    serialize/deserialize roundtrips (full + partial blocks), dedup
    stripping, chain-digest stability across *separate processes*, and
    rejection of every corruption mode (flipped payload bytes, tampered
    token history, truncation, stripped-but-unknown blocks, bad magic).
  * **Token identity** — the differential wall extended across the WAN:
    DC-prefill -> shipment -> edge-decode must produce exactly the tokens
    the single ragged engine produces, including prefix hits, CoW forks
    (fully-matched prompts), speculative decode on the decode side, and a
    decode pool too small to hold every imported block.
  * **Persistence** — the wire format doubles as the prefix-cache
    snapshot format: a restarted engine reloads the snapshot and serves
    warm prompts with cache hits and unchanged tokens.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import (DisaggregatedEngine, PagedDecodeEngine,
                               KVBlockRecord, KVShipment,
                               TransferIntegrityError, chain_digest,
                               payload_checksum)
    HAVE_JAX = True
except ImportError:                                    # pragma: no cover
    HAVE_JAX = False

pytestmark = pytest.mark.skipif(not HAVE_JAX, reason="jax not available")


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


COMMON = dict(cache_len=64, cache_dtype=jnp.float32,
              compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# wire format (synthetic payloads, no model needed)
# ---------------------------------------------------------------------------
def _fake_shipment(n_blocks=2, block_size=4, partial=(7, 8), seed=0):
    rng = np.random.default_rng(seed)
    blocks, parent = [], ""
    for i in range(n_blocks):
        tokens = [int(t) for t in rng.integers(0, 100, block_size)]
        digest = chain_digest(parent, tokens)
        payload = {"scan": {
            "k": rng.standard_normal((2, block_size, 1, 3)).astype(
                np.float32),
            "v": rng.standard_normal((2, block_size, 1, 3)).astype(
                np.float32)}}
        blocks.append(KVBlockRecord(digest=digest, parent=parent,
                                    tokens=tokens, payload=payload,
                                    checksum=payload_checksum(payload)))
        parent = digest
    return KVShipment(block_size=block_size, blocks=blocks,
                      partial_tokens=list(partial))


def test_roundtrip_full_and_partial_blocks():
    ship = _fake_shipment(n_blocks=3, partial=(42, 43, 44))
    back = KVShipment.deserialize(ship.serialize())
    assert back.block_size == ship.block_size
    assert back.partial_tokens == [42, 43, 44]
    assert back.n_blocks == 3 and back.n_payloads == 3
    for a, b in zip(ship.blocks, back.blocks):
        assert (a.digest, a.parent, a.tokens, a.checksum) \
            == (b.digest, b.parent, b.tokens, b.checksum)
        for part in a.payload:
            for kv in ("k", "v"):
                np.testing.assert_array_equal(a.payload[part][kv],
                                              b.payload[part][kv])
    # canonical bytes: re-serializing the roundtripped shipment is stable
    assert back.serialize() == ship.serialize()


def test_roundtrip_empty_and_payload_free():
    empty = KVShipment(block_size=4, blocks=[], partial_tokens=[1, 2])
    assert KVShipment.deserialize(empty.serialize()).partial_tokens == [1, 2]
    stripped = _fake_shipment().drop_payloads(
        {b.digest for b in _fake_shipment().blocks})
    assert stripped.n_payloads == 0 and stripped.payload_nbytes == 0
    back = KVShipment.deserialize(stripped.serialize())
    assert back.n_blocks == 2 and back.n_payloads == 0
    assert [b.checksum for b in back.blocks] \
        == [b.checksum for b in stripped.blocks]


def test_drop_payloads_is_selective():
    ship = _fake_shipment(n_blocks=3)
    keep = ship.blocks[1].digest
    deduped = ship.drop_payloads({b.digest for b in ship.blocks
                                  if b.digest != keep})
    assert deduped.n_payloads == 1
    assert deduped.blocks[1].payload is not None
    assert deduped.blocks[0].payload is None
    assert len(deduped.serialize()) < len(ship.serialize())


def test_digest_stability_across_processes():
    """Chain digests and serialized bytes are pure functions of content:
    a separate interpreter reproduces them bit-for-bit."""
    ship = _fake_shipment(n_blocks=2, seed=123)
    prog = (
        "import numpy as np\n"
        "from repro.serving import chain_digest, KVShipment, KVBlockRecord,"
        " payload_checksum\n"
        "rng = np.random.default_rng(123)\n"
        "blocks, parent = [], ''\n"
        "for i in range(2):\n"
        "    tokens = [int(t) for t in rng.integers(0, 100, 4)]\n"
        "    digest = chain_digest(parent, tokens)\n"
        "    payload = {'scan': {\n"
        "        'k': rng.standard_normal((2, 4, 1, 3)).astype(np.float32),\n"
        "        'v': rng.standard_normal((2, 4, 1, 3)).astype(np.float32)}}\n"
        "    blocks.append(KVBlockRecord(digest=digest, parent=parent,\n"
        "        tokens=tokens, payload=payload,\n"
        "        checksum=payload_checksum(payload)))\n"
        "    parent = digest\n"
        "ship = KVShipment(block_size=4, blocks=blocks,\n"
        "                  partial_tokens=[7, 8])\n"
        "print(blocks[-1].digest)\n"
        "import hashlib; print(hashlib.sha256(ship.serialize())"
        ".hexdigest())\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    other_digest, other_sha = out.stdout.split()
    assert other_digest == ship.blocks[-1].digest
    import hashlib
    assert other_sha == hashlib.sha256(ship.serialize()).hexdigest()


def test_corrupt_payload_rejected():
    data = bytearray(_fake_shipment().serialize())
    data[-5] ^= 0xFF                       # flip a byte inside KV payload
    with pytest.raises(TransferIntegrityError, match="checksum"):
        KVShipment.deserialize(bytes(data))


def test_tampered_token_history_rejected():
    ship = _fake_shipment()
    ship.blocks[0].tokens[0] ^= 1          # token no longer matches digest
    with pytest.raises(TransferIntegrityError, match="digest"):
        KVShipment.deserialize(ship.serialize())


def test_truncated_and_garbage_shipments_rejected():
    data = _fake_shipment().serialize()
    with pytest.raises(TransferIntegrityError):
        KVShipment.deserialize(data[:len(data) // 2])
    with pytest.raises(TransferIntegrityError, match="magic"):
        KVShipment.deserialize(b"not a shipment at all")


# ---------------------------------------------------------------------------
# engine export / import (real KV)
# ---------------------------------------------------------------------------
def _prefill(engine, prompt):
    engine.submit(np.asarray(prompt, np.int32), 1)
    return engine.run_until_drained()


def test_export_import_roundtrip_real_kv(model):
    """Exported device KV reimports bit-identically, and the importing
    engine then prefix-hits the prompt like it prefilled it locally."""
    cfg, api, params = model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)
    src = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    _prefill(src, prompt)
    ship = src.export_kv_prefix(prompt)
    assert ship.n_blocks == 37 // src.block_size
    assert len(ship.partial_tokens) == 37 % src.block_size
    back = KVShipment.deserialize(ship.serialize())

    dst = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    stats = dst.import_kv_shipment(back)
    assert stats["imported"] == ship.n_blocks
    assert stats["dedup_skipped"] == 0
    assert dst.cached_digests() == {b.digest for b in ship.blocks}
    # imported pool rows == exported pool rows, bit for bit
    for rec in ship.blocks:
        blk = dst.kv._cached[rec.digest]
        got = dst._read_block_payload(blk)
        for part in rec.payload:
            for kv in ("k", "v"):
                np.testing.assert_array_equal(got[part][kv],
                                              rec.payload[part][kv])
    # re-import is a pure dedup skip
    again = dst.import_kv_shipment(back)
    assert again["imported"] == 0
    assert again["dedup_skipped"] == ship.n_blocks


def test_import_rejects_stripped_unknown_block(model):
    cfg, api, params = model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    src = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    _prefill(src, prompt)
    ship = src.export_kv_prefix(prompt)
    stripped = ship.drop_payloads({b.digest for b in ship.blocks})
    dst = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    with pytest.raises(TransferIntegrityError, match="does not hold"):
        dst.import_kv_shipment(stripped)


# ---------------------------------------------------------------------------
# disaggregated serving: the differential wall across the WAN
# ---------------------------------------------------------------------------
def _fleet(cfg, seed=7):
    """Prefix-heavy fleet: 4 prompts sharing a 40-token preamble (prefix
    hits downstream), one short prompt (no full block), and one exact
    duplicate.  The first prompt is exactly 3 blocks long (48 tokens), so
    its shipped chain covers the *whole* feed — the decode-side cursor cap
    forces a write into the shared tail block, i.e. a CoW fork."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(
        0, cfg.vocab_size, n).astype(np.int32)])
        for n in (8, *(int(x) for x in rng.integers(3, 9, size=3)))]
    prompts.append(rng.integers(0, cfg.vocab_size, 7).astype(np.int32))
    prompts.append(prompts[0].copy())
    return prompts


def _run_disaggregated(api, params, prompts, max_new=8, **decode_kw):
    pf = PagedDecodeEngine(api, params, n_slots=4, **COMMON)
    de = PagedDecodeEngine(api, params, n_slots=4, **COMMON, **decode_kw)
    dis = DisaggregatedEngine(pf, de, dc_speedup=8.0)
    for p in prompts:
        dis.submit(p, max_new)
    done = {r.request_id: r.generated for r in dis.run_until_drained()}
    return dis, done


def test_disaggregated_token_identity_vs_single_engine(model):
    """The acceptance gate: prefill->transfer->decode output is exactly
    the single ragged engine's, with spec decode live on the decode side
    and prefix hits / CoW forks in the fleet."""
    cfg, api, params = model
    prompts = _fleet(cfg)
    one = PagedDecodeEngine(api, params, n_slots=4, **COMMON)
    for p in prompts:
        one.submit(p, 8)
    ref = {r.request_id: r.generated for r in one.run_until_drained()}

    dis, done = _run_disaggregated(api, params, prompts)
    assert done == ref
    s = dis.stats()
    assert s["handoff_checks"] == len(prompts)
    # the decode side really attached shipped blocks as prefix hits
    assert dis.decode.kv.prefix_hits >= 4
    assert dis.decode.kv.cow_copies >= 1          # duplicate prompt forks
    assert dis.decode.spec                        # speculation stayed on
    # content-addressed dedup: the shared preamble crossed the WAN once
    assert s["bytes_shipped"] < s["bytes_naive"]
    assert s["blocks_dedup_skipped"] > 0


def test_disaggregated_token_identity_without_spec(model):
    """Identity also holds with speculation pinned off at the edge (the
    plain one-token decode path)."""
    cfg, api, params = model
    prompts = _fleet(cfg, seed=11)[:4]
    one = PagedDecodeEngine(api, params, n_slots=4, spec=False, **COMMON)
    for p in prompts:
        one.submit(p, 6)
    ref = {r.request_id: r.generated for r in one.run_until_drained()}
    _, done = _run_disaggregated(api, params, prompts, max_new=6,
                                 spec=False)
    assert done == ref


def test_disaggregated_token_identity_under_pool_pressure(model):
    """A decode pool too small to keep every imported block still serves
    token-identically: imports drop (counted), the tail recomputes."""
    cfg, api, params = model
    prompts = _fleet(cfg, seed=13)
    one = PagedDecodeEngine(api, params, n_slots=4, **COMMON)
    for p in prompts:
        one.submit(p, 8)
    ref = {r.request_id: r.generated for r in one.run_until_drained()}
    # 18 non-null blocks: enough for ~2 live 48-token seqs, not the cache
    _, done = _run_disaggregated(api, params, prompts, num_blocks=19)
    assert done == ref


def test_disaggregated_charges_the_cost_model(model):
    """Transfer rides the §4.1 model on the shared SimClock: sim seconds
    grow with shipped bytes, and pricing at a slower link costs more."""
    cfg, api, params = model
    dis, _ = _run_disaggregated(api, params, _fleet(cfg, seed=17))
    bd = dis.clock.breakdown()
    assert bd["sim"] > 0 and bd["modeled"] > 0 and bd["real"] > 0
    assert bd["sim"] == pytest.approx(
        sum(r.duration for r in dis.transfer.records))
    slow = dis.priced_turnaround(1e6)["transfer"]
    fast = dis.priced_turnaround(1e10)["transfer"]
    assert slow > fast
    # crossover: monotone transfer => bandwidth above it wins, below loses
    base = dis.prefill_wall + dis.decode_wall
    bw = dis.crossover_bandwidth(base)
    if bw is not None:
        assert dis.priced_turnaround(bw * 2)["total"] <= base
        assert dis.priced_turnaround(bw / 2)["total"] > base


def test_disaggregated_rejects_mismatched_engines(model):
    cfg, api, params = model
    a = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    b = PagedDecodeEngine(api, params, n_slots=2, block_size=8, **COMMON)
    with pytest.raises(ValueError, match="block_size"):
        DisaggregatedEngine(a, b)
    c = PagedDecodeEngine(api, params, n_slots=2, prefix_cache=False,
                          **COMMON)
    with pytest.raises(ValueError, match="prefix_cache"):
        DisaggregatedEngine(a, c)


# ---------------------------------------------------------------------------
# prefix-cache persistence across restarts
# ---------------------------------------------------------------------------
def test_prefix_cache_persists_across_restart(model, tmp_path):
    """Snapshot -> new engine -> reload: warm prompts prefix-hit and the
    generated tokens match the pre-restart engine exactly."""
    cfg, api, params = model
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, 44).astype(np.int32)
    eng = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    eng.submit(prompt, 8)
    ref = eng.run_until_drained()[0].generated
    path = str(tmp_path / "prefix_cache.kvship")
    nbytes = eng.save_prefix_cache(path)
    assert nbytes == os.path.getsize(path) > 0

    fresh = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    stats = fresh.load_prefix_cache(path)
    assert stats["imported"] >= 44 // fresh.block_size
    fresh.submit(prompt, 8)
    assert fresh.run_until_drained()[0].generated == ref
    assert fresh.kv.prefix_hits >= 1
    assert fresh.kv.prefix_tokens_reused >= (44 // fresh.block_size - 1) \
        * fresh.block_size


def test_persisted_snapshot_corruption_detected(model, tmp_path):
    cfg, api, params = model
    rng = np.random.default_rng(29)
    eng = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    _prefill(eng, rng.integers(0, cfg.vocab_size, 33).astype(np.int32))
    path = str(tmp_path / "c.kvship")
    eng.save_prefix_cache(path)
    data = bytearray(open(path, "rb").read())
    data[-3] ^= 0x10
    open(path, "wb").write(bytes(data))
    fresh = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    with pytest.raises(TransferIntegrityError):
        fresh.load_prefix_cache(path)
