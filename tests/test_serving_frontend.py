"""Async streaming frontend + open-loop serving: the turnaround wall.

Pins the tentpole contracts of serving/frontend.py:

  * **Streaming identity** — tokens delivered through the step-thread
    ``on_token`` hook / :meth:`AsyncEngine.stream` are exactly the
    engine's batch-mode outputs, in order, for every concurrent request.
  * **Disconnect frees KV** — a consumer that cancels its stream aborts
    the request mid-flight and the engine reclaims its blocks (the pool
    returns to the state a never-submitted run would show).
  * **Open loop** — :func:`run_open_loop` is deterministic given a seeded
    arrival schedule, meets goodput 1.0 at light load, sheds under
    overload when a TTFT target is set, and stamps every latency mark
    from the shared SimClock (TTFT comparable across engine kinds —
    including the disaggregated coordinator's engines).
  * **Priority classes** — a higher class admits before earlier-queued
    lower-class requests; tokens are unchanged (greedy decode is
    schedule-independent).
"""
import asyncio

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.simclock import SimClock
from repro.models import build_model
from repro.serving import (AsyncEngine, OpenRequest, PagedDecodeEngine,
                           run_open_loop)

COMMON = dict(cache_len=64, cache_dtype=jnp.float32,
              compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(cfg, n, seed=0, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def _batch_ref(api, params, prompts, max_new=8, **kw):
    eng = PagedDecodeEngine(api, params, **kw)
    for p in prompts:
        eng.submit(p, max_new)
    return {r.request_id: r.generated for r in eng.run_until_drained()}


# ---------------------------------------------------------------------------
def test_async_engine_streaming_token_identical(model):
    """Concurrent requests through the async frontend: per-token sink
    deliveries arrive in order and equal both the resolved request's
    ``generated`` and the batch-mode oracle."""
    cfg, api, params = model
    prompts = _prompts(cfg, 5, seed=11)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=8,
              prefix_cache=True, **COMMON)
    ref = _batch_ref(api, params, prompts, 8, **kw)
    eng = PagedDecodeEngine(api, params, **kw)
    streamed: dict = {}

    def sink_for(i):
        streamed[i] = []
        return lambda tok, fin: (tok is not None
                                 and streamed[i].append(tok))

    with AsyncEngine(eng) as fe:
        tickets = [fe.submit(p, 8, sink=sink_for(i))
                   for i, p in enumerate(prompts)]
        results = [fe.result(t, timeout=300) for t in tickets]
    for i, r in enumerate(results):
        assert not r.cancelled and not r.shed
        assert streamed[i] == r.generated == ref[i]


def test_async_stream_disconnect_cancels_and_frees_kv(model):
    """An asyncio consumer that disconnects mid-stream aborts its request
    on the engine; the survivor streams to completion token-identically
    and the cancelled sequence's blocks are reclaimed."""
    cfg, api, params = model
    prompts = _prompts(cfg, 2, seed=13, lo=8, hi=12)
    kw = dict(n_slots=2, block_size=4, chunk_tokens=8,
              prefix_cache=False, **COMMON)
    ref = _batch_ref(api, params, prompts, 12, **kw)
    eng = PagedDecodeEngine(api, params, **kw)

    async def go():
        with AsyncEngine(eng) as fe:
            async def consume(i, limit=None):
                toks = []
                async for tok in fe.stream(prompts[i], 12):
                    toks.append(tok)
                    if limit and len(toks) >= limit:
                        break        # disconnect: generator closes
                return toks
            return await asyncio.gather(consume(0, limit=3), consume(1))

    got0, got1 = asyncio.run(go())
    assert got1 == ref[1]                      # survivor: full stream
    assert got0 == ref[0][:len(got0)]          # prefix before disconnect
    assert eng.cancelled == 1
    assert eng.stats()["released_seqs"] == 1
    # the aborted sequence's blocks went back to the pool
    assert eng.kv.allocator.num_allocated == 0
    assert not eng.scheduler.running and not eng.scheduler.waiting


def test_open_loop_goodput_and_determinism_token_identical(model):
    """Seeded Poisson-ish arrivals at light load on a SimClock: every
    request completes (goodput 1.0 with no targets), TTFT marks are
    finite and ordered, and a rerun reproduces the records exactly
    (virtual idle time is simulated, compute is measured)."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, seed=17)
    rng = np.random.default_rng(17)
    arrivals = np.cumsum(rng.exponential(5.0, len(prompts)))

    def run_once():
        eng = PagedDecodeEngine(api, params, n_slots=3, block_size=4,
                                chunk_tokens=8, prefix_cache=True,
                                **COMMON)
        reqs = [OpenRequest(p, 6, t_arrival=float(t))
                for p, t in zip(prompts, arrivals)]
        return eng, run_open_loop(eng, reqs, clock=SimClock())

    eng, out = run_once()
    assert out["offered"] == len(prompts)
    assert out["completed"] == len(prompts)
    assert out["goodput_ratio"] == 1.0
    assert out["cancelled"] == 0 and out["shed"] == 0
    for rec in out["records"]:
        assert rec["status"] == "ok" and rec["ttft"] is not None
        assert rec["ttft"] > 0 and rec["tokens"] == 6
    assert out["ttft_p50"] is not None and out["ttft_p95"] is not None
    # deterministic tokens: the finished requests match the batch oracle
    # (request ids are assigned in arrival order in both worlds)
    ref = _batch_ref(api, params, prompts, 6, n_slots=3, block_size=4,
                     chunk_tokens=8, prefix_cache=True, **COMMON)
    _, out2 = run_once()
    toks = {r["request_id"]: r["tokens"] for r in out["records"]}
    toks2 = {r["request_id"]: r["tokens"] for r in out2["records"]}
    assert toks == toks2
    assert toks == {i: len(v) for i, v in ref.items()}


def test_open_loop_cancel_after_and_slo_shed(model):
    """Overload + disconnects: all requests arrive at once on one lane
    with a tight TTFT target — the tail is shed (never admitted past its
    deadline), explicit ``cancel_after`` disconnects are excluded from
    the goodput denominator, and the books balance."""
    cfg, api, params = model
    prompts = _prompts(cfg, 8, seed=19, lo=8, hi=14)
    eng = PagedDecodeEngine(api, params, n_slots=1, block_size=4,
                            chunk_tokens=4, prefix_cache=True, **COMMON)
    reqs = [OpenRequest(p, 8, t_arrival=0.0) for p in prompts]
    reqs[0] = OpenRequest(prompts[0], 8, t_arrival=0.0,
                          cancel_after=1e-6)
    out = run_open_loop(eng, reqs, clock=SimClock(),
                        ttft_target=1e-9)
    assert out["offered"] == len(prompts)
    assert out["shed"] > 0                     # the deadline did bite
    assert out["completed"] + out["shed"] + out["cancelled"] == \
        len(prompts)
    assert out["goodput_ratio"] <= 1.0
    assert eng.shed == out["shed"] and eng.stats()["shed"] == out["shed"]
    # after the drain nothing leaks: no live seqs, pool back to cache-only
    assert not eng.scheduler.running and not eng.scheduler.waiting
    assert not eng.kv.take_swap_ins()


def test_priority_class_admits_first_token_identical(model):
    """Three same-size requests on one lane, the LAST submitted carrying
    a higher priority class: it must be admitted (and finish) first,
    while every request's tokens still match the batch oracle."""
    cfg, api, params = model
    prompts = _prompts(cfg, 3, seed=23, lo=6, hi=7)
    kw = dict(n_slots=1, block_size=4, chunk_tokens=8,
              prefix_cache=False, **COMMON)
    ref = _batch_ref(api, params, prompts, 4, **kw)
    eng = PagedDecodeEngine(api, params, **kw)
    eng.submit(prompts[0], 4, priority=0)
    eng.submit(prompts[1], 4, priority=0)
    eng.submit(prompts[2], 4, priority=5)
    fin = []
    for _ in range(200):
        eng.step()
        fin += eng.take_finished()
        if fin:
            break
    assert fin and fin[0].request_id == 2, \
        "high-priority request did not go first"
    fin += eng.run_until_drained()
    assert {r.request_id: r.generated for r in fin} == ref


def test_simclock_stamps_make_ttft_comparable(model):
    """With a shared SimClock installed, t_submit / t_first_token /
    t_done come from virtual time: idle gaps show up in TTFT, and the
    clock runs live inside ``measure`` so mid-step stamps land inside
    the step window — the satellite that makes disaggregated and
    wall-clock TTFT rows comparable."""
    cfg, api, params = model
    clock = SimClock()
    eng = PagedDecodeEngine(api, params, n_slots=1, clock=clock,
                            **COMMON)
    prompt = _prompts(cfg, 1, seed=29)[0]
    clock.advance(100.0, "pre-submit idle")
    rid = eng.submit(prompt, 3)
    req = eng.scheduler.waiting[0]
    assert req.t_submit == pytest.approx(100.0)
    clock.advance(7.0, "queueing")
    while eng.has_work():
        with clock.measure("step"):
            eng.step()
    done = eng.run_until_drained()[0]
    assert done.request_id == rid
    assert done.t_first_token >= 107.0         # stamped in virtual time
    assert done.t_done >= done.t_first_token
    assert clock.now >= done.t_done
    # live `now` inside measure: stamps fell within the measured window,
    # not at its start
    assert done.t_first_token > 107.0


def test_disaggregated_engines_share_the_coordinator_clock(model):
    """The DisaggregatedEngine wires its SimClock into both member
    engines, so their latency stamps live on the same virtual timeline
    as the WAN/transfer costs."""
    cfg, api, params = model
    from repro.serving import DisaggregatedEngine
    kw = dict(n_slots=2, block_size=4, prefix_cache=True, **COMMON)
    pf = PagedDecodeEngine(api, params, **kw)
    de = PagedDecodeEngine(api, params, **kw)
    dd = DisaggregatedEngine(pf, de, dc_speedup=8.0)
    assert pf.clock is dd.clock and de.clock is dd.clock
    dd.submit(_prompts(cfg, 1, seed=31)[0], 4)
    done = dd.run_until_drained()
    assert len(done) == 1
    assert done[0].t_first_token > 0.0
    assert done[0].t_done >= done[0].t_first_token
