"""Loop-aware HLO collective accounting (roofline/hlo_parse.py)."""
import pytest

from repro.roofline import hlo_parse as hp

HLO = """
HloModule jit_step

%cond_inner (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(4)
  ROOT %lt = pred[] compare(%iv, %bound), direction=LT
}

%body_inner (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%iv2, %ar)
}

%cond_outer (q: (s32[], f32[8])) -> pred[] {
  %q = (s32[], f32[8]) parameter(0)
  %iv = s32[] get-tuple-element(%q), index=0
  %bound = s32[] constant(3)
  ROOT %lt = pred[] compare(%iv, %bound), direction=LT
}

%body_outer (q: (s32[], f32[8])) -> (s32[], f32[8]) {
  %q = (s32[], f32[8]) parameter(0)
  %w = (s32[], f32[8]) while(%q), condition=%cond_inner, body=%body_inner
  %y = f32[16]{0} all-gather(%x2), dimensions={0}
  ROOT %t = (s32[], f32[8]) tuple(%iv3, %x3)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %w0 = (s32[], f32[8]) while(%init), condition=%cond_outer, body=%body_outer
  %final = f32[32]{0} all-reduce(%z), to_apply=%add
  ROOT %r = f32[8]{0} get-tuple-element(%w0), index=1
}
"""


def test_flat_counts_bodies_once():
    flat = hp.collective_bytes(HLO)
    # one all-reduce in inner body (32B) + entry (128B); one all-gather (64B)
    assert flat["all-reduce"]["bytes"] == 8 * 4 + 32 * 4
    assert flat["all-gather"]["bytes"] == 16 * 4


def test_loop_aware_multiplies_by_trip_counts():
    aware = hp.collective_bytes_loop_aware(HLO, entry_hint="main")
    # inner all-reduce: 8*4 bytes x 4 inner trips x 3 outer trips = 384
    # entry all-reduce: 128
    assert aware["all-reduce"]["bytes"] == 8 * 4 * 4 * 3 + 32 * 4
    # outer-body all-gather: 64 x 3 trips
    assert aware["all-gather"]["bytes"] == 16 * 4 * 3


def test_trip_count_extraction():
    comps = hp._split_computations(HLO)
    assert hp._trip_count(comps["cond_inner"]) == 4
    assert hp._trip_count(comps["cond_outer"]) == 3
    assert hp._trip_count("no constants here") == 1


def test_start_done_not_double_counted():
    hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %s = f32[64]{0} all-gather-start(%a), dimensions={0}
  %d = f32[64]{0} all-gather-done(%s)
}
"""
    flat = hp.collective_bytes(hlo)
    assert flat["all-gather"]["count"] == 1
    assert flat["all-gather"]["bytes"] == 64 * 4
