"""Flash-attention Pallas kernel vs pure-jnp oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_reference


def _mk(key, B, H, Hkv, S, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Hkv, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Hkv, S, D), jnp.float32).astype(dtype)
    return q, k, v


SWEEP = [
    # B, H, Hkv, S, D, window, bq, bkv
    (1, 1, 1, 128, 32, 0, 64, 64),
    (2, 4, 2, 256, 64, 0, 64, 64),
    (2, 4, 1, 256, 64, 0, 128, 64),     # MQA
    (1, 8, 8, 256, 16, 0, 64, 128),     # MHA, small head dim
    (2, 4, 2, 256, 64, 96, 64, 64),     # sliding window
    (1, 2, 2, 512, 64, 128, 128, 128),  # window = block
    (1, 2, 1, 384, 48, 100, 64, 64),    # non-pow2 window, odd D
]


@pytest.mark.parametrize("B,H,Hkv,S,D,window,bq,bkv", SWEEP)
def test_flash_vs_ref_f32(key, B, H, Hkv, S, D, window, bq, bkv):
    q, k, v = _mk(key, B, H, Hkv, S, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_kv=bkv, interpret=True)
    ref = attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [0, 96])
def test_flash_vs_ref_bf16(key, window):
    q, k, v = _mk(key, 2, 4, 2, 256, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = attention_reference(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True,
                              window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=3e-2, rtol=3e-2)


def test_wrapper_pads_and_transposes(key):
    # model layout (B,S,H,D) with S not a block multiple
    B, S, H, Hkv, D = 2, 200, 4, 2, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    out = ops.flash_attention_bshd(q, k, v, block_q=64, block_kv=64,
                                   interpret=True)
    ref = attention_reference(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(ref, 1, 2)),
                               atol=2e-5, rtol=2e-5)
    assert out.shape == (B, S, H, D)


def test_flash_matches_model_attention(key):
    """The kernel and the model's XLA chunked path agree."""
    from repro.models.layers import chunked_attention
    B, S, H, Hkv, D = 2, 256, 4, 2, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    a = ops.flash_attention_bshd(q, k, v, block_q=64, block_kv=64,
                                 interpret=True)
    b = chunked_attention(q, k, v, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
