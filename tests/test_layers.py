"""Layer-level unit + property tests: attention paths, RoPE, norms, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.models import layers
from repro.models.common import apply_norm, apply_rope, norm_params


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window", [0, 48, 1000])
def test_chunked_matches_full(key, window):
    B, S, H, Hkv, D = 2, 256, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    a = layers.chunked_attention(q, k, v, window=window, chunk=64)
    b = layers.full_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [0, 48])
def test_chunked_attention_grads(key, window):
    B, S, H, Hkv, D = 1, 128, 2, 1, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    g1 = jax.grad(lambda k_: layers.chunked_attention(
        q, k_, v, window=window, chunk=32).sum())(k)
    g2 = jax.grad(lambda k_: layers.full_attention(
        q, k_, v, causal=True, window=window).sum())(k)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_decode_attention_matches_full(key):
    """One-token decode vs last row of full attention."""
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    ks = jax.random.split(key, 3)
    q_all = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    full = layers.full_attention(q_all, k, v, causal=True)

    slot_positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos = jnp.full((B,), S - 1)
    dec = layers.decode_attention(q_all[:, -1:], k, v, slot_positions, pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_relativity(key):
    x = jax.random.normal(key, (2, 16, 4, 32))
    pos = jnp.arange(16)
    y = apply_rope(x, pos, 10000.0)
    # rotation preserves per-head norms
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-5)
    # dot products depend only on relative distance
    q = apply_rope(x, pos, 10000.0)
    k = apply_rope(x, pos, 10000.0)
    d1 = jnp.einsum("d,d->", q[0, 3, 0], k[0, 1, 0])
    q2 = apply_rope(x, pos + 7, 10000.0)
    k2 = apply_rope(x, pos + 7, 10000.0)
    d2 = jnp.einsum("d,d->", q2[0, 3, 0], k2[0, 1, 0])
    np.testing.assert_allclose(float(d1), float(d2), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([8, 32, 96]), kind=st.sampled_from(
    ["rmsnorm", "layernorm"]))
def test_norm_properties(d, kind):
    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (4, d)) * 10 + 3
    p = norm_params(kind, d)
    y = apply_norm(kind, p, x)
    yf = np.asarray(y, np.float32)
    if kind == "layernorm":
        np.testing.assert_allclose(yf.mean(-1), 0.0, atol=1e-4)
        np.testing.assert_allclose(yf.var(-1), 1.0, atol=1e-2)
    else:
        np.testing.assert_allclose((yf ** 2).mean(-1), 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
def test_moe_dispatch_invariants(key):
    """Every kept token-slot lands in exactly one (expert, capacity) cell;
    combine weights renormalize over kept slots."""
    from repro.models import moe as moe_lib
    cfg = get_config("deepseek-moe-16b").smoke_variant()
    p = moe_lib.moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(aux)
    assert float(aux) > 0


def test_moe_capacity_bound():
    from repro.models.moe import expert_capacity
    from repro.configs.base import MoEConfig
    mo = MoEConfig(n_experts=8, experts_per_token=2, d_expert=16,
                   capacity_factor=1.25)
    c = expert_capacity(64, mo)
    assert c == int(np.ceil(64 * 2 / 8 * 1.25))


@settings(max_examples=15, deadline=None)
@given(S=st.sampled_from([4, 16, 64]), k=st.integers(1, 3))
def test_route_topk_property(S, k):
    """Gates are positive and sum to 1 over the k selected experts."""
    from repro.models.moe import route_topk
    key = jax.random.PRNGKey(S * 10 + k)
    logits = jax.random.normal(key, (2, S, 8))
    gates, idx = route_topk(logits, k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.max()) < 8
    # chosen experts are distinct per token
    for b in range(2):
        for s in range(S):
            sel = np.asarray(idx[b, s])
            assert len(set(sel.tolist())) == k
