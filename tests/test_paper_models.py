"""The paper's own DNNs: structure, parameter counts, trainability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import BraggNNConfig, CookieNetAEConfig
from repro.models import braggnn, cookienetae
from repro.models.common import count_params


def test_braggnn_structure(key):
    cfg = BraggNNConfig()
    params = braggnn.init_params(key, cfg)
    n = count_params(params)
    # BraggNN reference is ~45K params; ours is the same scale
    assert 10_000 < n < 100_000
    out = braggnn.forward(params, jnp.zeros((4, 11, 11, 1)), cfg)
    assert out.shape == (4, 2)
    assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0


def test_cookienetae_structure(key):
    cfg = CookieNetAEConfig()
    params = cookienetae.init_params(key, cfg)
    n = count_params(params)
    # paper reports 343,937; reference widths aren't public — assert the
    # 8-conv stack lands within 2% of the paper's count
    assert abs(n - 343_937) / 343_937 < 0.02
    x = jnp.ones((2, 16, 128, 1))
    out = cookienetae.forward(params, x, cfg)
    assert out.shape == (2, 16, 128, 1)
    # output is a pdf along the energy axis
    np.testing.assert_allclose(np.asarray(out[..., 0].sum(-1)), 1.0,
                               atol=1e-4)


def test_cookienetae_learns(key):
    from repro.data.synthetic import cookiebox_shots
    from repro.optim import adam

    cfg = CookieNetAEConfig()
    params = cookienetae.init_params(key, cfg)
    opt = adam(1e-3)
    state = opt.init(params)
    d = cookiebox_shots(key, 16)
    batch = {"images": d["images"], "targets": d["targets"]}

    @jax.jit
    def step(p, s):
        (l, _), g = jax.value_and_grad(
            lambda p_: cookienetae.loss_fn(p_, batch, cfg),
            has_aux=True)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, l

    losses = []
    for _ in range(20):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8
