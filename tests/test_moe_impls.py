"""§Perf-1 MoE dispatch implementations: gather == gshard, incl. gradients,
under every family config and under a real (multi-device) mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_lib

MOE_ARCHS = ["deepseek-moe-16b", "qwen3-moe-235b-a22b",
             "moonshot-v1-16b-a3b"]


def _setup(arch, impl, key):
    cfg = get_config(arch).smoke_variant()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl=impl))
    p = moe_lib.moe_params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, 16, cfg.d_model), jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_gather_matches_gshard(key, arch):
    cfg_g, p, x = _setup(arch, "gshard", key)
    cfg_f, _, _ = _setup(arch, "gather", key)
    y1, a1 = moe_lib.apply_moe(p, x, cfg_g)
    y2, a2 = moe_lib.apply_moe(p, x, cfg_f)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


@pytest.mark.parametrize("arch", ["deepseek-moe-16b"])
def test_gather_grads_match_gshard(key, arch):
    cfg_g, p, x = _setup(arch, "gshard", key)
    cfg_f, _, _ = _setup(arch, "gather", key)
    g1 = jax.grad(lambda p_: moe_lib.apply_moe(p_, x, cfg_g)[0].sum())(p)
    g2 = jax.grad(lambda p_: moe_lib.apply_moe(p_, x, cfg_f)[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)


def test_gather_under_mesh_uses_shard_map_combine(key):
    """With an active mesh the expert-parallel combine path runs and must
    agree with the no-mesh fallback."""
    if not hasattr(jax, "set_mesh") or not hasattr(jax.sharding, "AxisType"):
        pytest.skip("explicit-sharding mesh API requires jax >= 0.5")
    cfg, p, x = _setup("deepseek-moe-16b", "gather", key)
    y_ref, _ = moe_lib.apply_moe(p, x, cfg)

    n = len(jax.devices())
    if n < 2:
        # single device: still exercise the mesh path (1x1 mesh)
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((1, n), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        y_mesh, _ = jax.jit(
            lambda p_, x_: moe_lib.apply_moe(p_, x_, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_mesh),
                               atol=5e-5, rtol=5e-4)


def test_capacity_drops_respected_in_both_impls(key):
    """Force a tiny capacity so drops occur; both impls must drop the SAME
    token-slots (same deterministic cumsum order)."""
    cfg0 = get_config("deepseek-moe-16b").smoke_variant()
    tiny = dataclasses.replace(cfg0.moe, capacity_factor=0.26)
    y = {}
    for impl in ("gshard", "gather"):
        cfg = dataclasses.replace(
            cfg0, moe=dataclasses.replace(tiny, impl=impl))
        p = moe_lib.moe_params(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (2, 16, cfg.d_model), jnp.float32)
        y[impl], _ = moe_lib.apply_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y["gshard"]),
                               np.asarray(y["gather"]),
                               atol=2e-5, rtol=2e-5)
