"""funcX fabric + hybrid clock semantics."""
import time

import pytest

from repro.core import build_system
from repro.core.simclock import SimClock


def test_clock_kinds_and_breakdown():
    c = SimClock()
    c.advance(2.0, "wan", "sim")
    c.charge(19.0, "dcai train")
    with c.measure("real step"):
        time.sleep(0.01)
    br = c.breakdown()
    assert br["sim"] == pytest.approx(2.0)
    assert br["modeled"] == pytest.approx(19.0)
    assert br["real"] >= 0.01
    assert br["total"] == pytest.approx(sum(
        (br["sim"], br["modeled"], br["real"])))
    tl = c.timeline()
    assert [e[1] for e in tl] == ["sim", "modeled", "real"]
    assert tl[1][0] == pytest.approx(2.0)      # started after the WAN advance


def test_clock_rejects_negative():
    c = SimClock()
    with pytest.raises(AssertionError):
        c.advance(-1.0)


def test_funcx_real_vs_modeled_endpoints():
    sys_ = build_system()

    def work(x):
        time.sleep(0.02)
        return x * 2

    fid = sys_.funcx.register_function(work)
    ep_real = sys_.funcx.register_endpoint("local-v100", mode="real")
    ep_model = sys_.funcx.register_endpoint("cerebras", mode="modeled")

    r1 = sys_.funcx.run(ep_real, fid, 21)
    assert r1.result == 42 and r1.mode == "real"
    assert r1.duration >= 0.02

    r2 = sys_.funcx.run(ep_model, fid, 21, modeled_duration=19.0)
    assert r2.result == 42 and r2.mode == "modeled"
    assert r2.duration == pytest.approx(19.0)

    br = sys_.clock.breakdown()
    assert br["modeled"] == pytest.approx(19.0)
    # service overhead charged for both invocations
    assert br["sim"] >= r1.overhead + r2.overhead - 1e-6


def test_funcx_speedup_scaling():
    sys_ = build_system()

    def work():
        time.sleep(0.05)
        return "ok"

    fid = sys_.funcx.register_function(work)
    ep = sys_.funcx.register_endpoint("cerebras", mode="modeled",
                                      speedup_vs_host=50.0)
    r = sys_.funcx.run(ep, fid)
    # modeled duration = wall / speedup
    assert r.duration < 0.05
    assert r.duration == pytest.approx(0.05 / 50.0, rel=0.5)


def test_unknown_endpoint_or_function_raises():
    sys_ = build_system()
    with pytest.raises(KeyError):
        sys_.funcx.run("nope", "also-nope")
