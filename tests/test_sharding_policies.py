"""§Perf sharding policies: divisibility safety + intent."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch import specs as specs_lib
from repro.models import build_model

AXES = {"data": 16, "model": 16}
AXES_MP = {"pod": 2, "data": 16, "model": 16}


def _prod(entry, axes):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= axes[a]
        return n
    return axes[entry]


@pytest.mark.parametrize("policy", ["replicated", "local_recurrent",
                                    "fsdp_flat"])
@pytest.mark.parametrize("arch", ["xlstm-1.3b", "whisper-base",
                                  "qwen3-moe-235b-a22b"])
def test_policy_specs_divisible(arch, policy):
    cfg = get_config(arch)
    api = build_model(cfg)
    tree = specs_lib.abstract_params(api)
    specs = sh.param_specs(tree, AXES_MP, data_axes=("pod", "data"),
                           policy=policy)
    for leaf, spec in zip(
            jax.tree.leaves(tree),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for d, s in zip(leaf.shape, spec):
            assert d % _prod(s, AXES_MP) == 0, (arch, policy, leaf.shape,
                                                spec)


def test_replicated_policy_replicates_everything():
    cfg = get_config("whisper-base")
    api = build_model(cfg)
    tree = specs_lib.abstract_params(api)
    specs = sh.param_specs(tree, AXES, policy="replicated")
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(s is None for s in spec)


def test_fsdp_flat_shards_exactly_one_dim_of_big_leaves():
    cfg = get_config("xlstm-1.3b")
    api = build_model(cfg)
    tree = specs_lib.abstract_params(api)
    specs = sh.param_specs(tree, AXES, policy="fsdp_flat")
    n_sharded = 0
    for leaf, spec in zip(
            jax.tree.leaves(tree),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        sharded_dims = [s for s in spec if s is not None]
        assert len(sharded_dims) <= 1
        if leaf.size >= (1 << 23):
            assert len(sharded_dims) == 1, (leaf.shape, spec)
            n_sharded += 1
        else:
            assert len(sharded_dims) == 0    # small leaves replicated
    assert n_sharded > 0


def test_constrain_noop_without_mesh(key):
    import jax.numpy as jnp
    from repro.models.common import constrain
    x = jnp.ones((8, 4))
    y = constrain(x, "batch", "model")
    assert (y == x).all()


def test_constrain_respects_divisibility():
    import jax.numpy as jnp
    from repro.models.common import constrain
    if not hasattr(jax, "set_mesh") or not hasattr(jax.sharding, "AxisType"):
        pytest.skip("explicit-sharding mesh API requires jax >= 0.5")
    n = len(jax.devices())
    mesh = jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        # 7 doesn't divide the model axis unless n == 1 or 7
        out = jax.jit(lambda x: constrain(x, "batch", "model"))(
            jnp.ones((2, 7)))
        assert out.shape == (2, 7)
