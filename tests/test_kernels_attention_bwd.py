"""Flash-attention BACKWARD Pallas kernels vs jax.grad of the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_trainable
from repro.kernels.ref import attention_reference

SWEEP = [
    # B, H, Hkv, S, D, window, bq, bkv
    (1, 2, 1, 128, 32, 0, 64, 64),
    (2, 4, 2, 256, 32, 0, 64, 64),
    (2, 4, 2, 256, 32, 96, 64, 64),
    (1, 4, 4, 128, 64, 0, 64, 32),   # MHA, rectangular blocks
    (1, 8, 2, 128, 16, 40, 32, 32),  # deep GQA + window
]


@pytest.mark.parametrize("B,H,Hkv,S,D,window,bq,bkv", SWEEP)
def test_flash_grads_match_reference(key, B, H, Hkv, S, D, window, bq, bkv):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    ct = jax.random.normal(ks[3], (B, H, S, D))

    def f(q_, k_, v_):
        o = flash_attention_trainable(q_, k_, v_, True, window, bq, bkv,
                                      True)
        return (o * ct).sum()

    def r(q_, k_, v_):
        o = attention_reference(q_, k_, v_, causal=True, window=window)
        return (o * ct).sum()

    gk = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)


def test_forward_value_unchanged_by_custom_vjp(key):
    B, H, Hkv, S, D = 1, 2, 1, 128, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    o1 = flash_attention_trainable(q, k, v, True, 0, 64, 64, True)
    o2 = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=2e-5)
