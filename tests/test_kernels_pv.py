"""Pseudo-Voigt kernel vs oracle + hypothesis property: center recovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import pseudo_voigt_reference, pv_profile


def _patches(key, n, cy, cx, g, amp=100.0, noise=0.5, p=11):
    yy, xx = jnp.mgrid[0:p, 0:p]

    def mk(cy_, cx_, g_):
        return pv_profile(yy - cy_, g_) * pv_profile(xx - cx_, g_)

    img = jax.vmap(mk)(cy, cx, g) * amp
    return img + noise * jax.random.normal(key, img.shape)


def test_kernel_matches_reference(key):
    ks = jax.random.split(key, 4)
    n = 96
    cy = jax.random.uniform(ks[0], (n,), minval=3.0, maxval=8.0)
    cx = jax.random.uniform(ks[1], (n,), minval=3.0, maxval=8.0)
    g = jax.random.uniform(ks[2], (n,), minval=0.8, maxval=1.8)
    patches = _patches(ks[3], n, cy, cx, g)
    out_k = ops.pseudo_voigt_fit(patches, block=32, interpret=True)
    out_r = pseudo_voigt_reference(patches)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    cy=st.floats(3.5, 7.5), cx=st.floats(3.5, 7.5),
    gamma=st.floats(0.8, 1.6), amp=st.floats(20.0, 300.0),
)
def test_center_recovery_property(cy, cx, gamma, amp):
    """For any clean pseudo-Voigt peak the fitter recovers its center."""
    key = jax.random.PRNGKey(int(cy * 1000) ^ int(cx * 917))
    patches = _patches(key, 1, jnp.array([cy]), jnp.array([cx]),
                       jnp.array([gamma]), amp=amp, noise=0.0)
    fit = ops.pseudo_voigt_fit(patches, block=8, interpret=True)
    assert abs(float(fit[0, 0]) - cy) < 0.05
    assert abs(float(fit[0, 1]) - cx) < 0.05
    assert float(fit[0, 2]) > 0


def test_padding_path(key):
    patches = _patches(key, 7, jnp.full((7,), 5.0), jnp.full((7,), 5.0),
                       jnp.full((7,), 1.2))
    out = ops.pseudo_voigt_fit(patches, block=8, interpret=True)
    assert out.shape == (7, 6)
    assert np.all(np.isfinite(np.asarray(out)))


def test_analysis_op_labels(key):
    """analysis.label_for_braggnn produces normalized centers in [0,1]."""
    from repro.analysis import label_for_braggnn
    from repro.data.synthetic import bragg_patches
    d = bragg_patches(key, 32)
    labels = label_for_braggnn(d["patches"])
    assert labels.shape == (32, 2)
    a = np.asarray(labels)
    assert a.min() >= 0.0 and a.max() <= 1.0
    # labels should be close to the ground-truth centers
    assert float(jnp.abs(labels - d["centers"]).mean()) < 0.05
