"""Per-architecture smoke tests (system contract §f): a REDUCED variant of
each assigned family runs one forward/train step on CPU, asserting output
shapes and no NaNs; plus one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model


def _batch(key, cfg, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_positions, cfg.frontend.d_embed))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend.n_tokens, cfg.frontend.d_embed))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_variant_limits(arch):
    cfg = get_config(arch).smoke_variant()
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(key, arch):
    cfg = get_config(arch).smoke_variant()
    api = build_model(cfg)
    params = api.init(key)
    batch = _batch(key, cfg)
    loss, metrics = api.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nans(key, arch):
    from repro.configs.shapes import InputShape
    from repro.launch import specs as specs_lib

    cfg = get_config(arch).smoke_variant()
    api = build_model(cfg)
    shape = InputShape("t", 32, 2, "train")
    step, opt = specs_lib.make_train_step_fn(api, shape, lr=1e-3)
    params = api.init(key)
    opt_state = opt.init(params)
    batch = _batch(key, cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0, arch
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(key, arch):
    cfg = get_config(arch).smoke_variant()
    api = build_model(cfg)
    params = api.init(key)
    B = 2
    cache = api.init_cache(B, 64)
    tokens = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = api.decode_step(params, cache, tokens)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache position advanced
    assert int(new_cache["pos"][0]) == 1
