"""Optimizers: convergence + invariant properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.optim import adafactor, adam, adamw, sgd
from repro.optim.schedules import constant, inverse_sqrt, warmup_cosine


def _quadratic(params):
    return sum(jnp.sum((p - 3.0) ** 2) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("make_opt", [
    lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.001),
    lambda: sgd(0.05, momentum=0.9), lambda: adafactor(0.3),
])
def test_converges_on_quadratic(make_opt):
    opt = make_opt()
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    loss0 = float(_quadratic(params))
    for _ in range(150):
        grads = jax.grad(_quadratic)(params)
        params, state = opt.update(grads, state, params)
    assert float(_quadratic(params)) < loss0 * 1e-2


def test_grad_clipping_bounds_update():
    opt = adam(0.1, grad_clip_norm=1.0)
    params = {"w": jnp.zeros((10,))}
    state = opt.init(params)
    huge = {"w": jnp.full((10,), 1e9)}
    new, _ = opt.update(huge, state, params)
    # adam step is bounded by lr regardless, but clipped grads keep m sane
    assert float(jnp.abs(new["w"]).max()) <= 0.11


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-5, 1e-1), steps=st.integers(1, 50))
def test_adam_step_size_bounded(lr, steps):
    """|update| <= ~lr per step (Adam's invariant)."""
    opt = adam(lr)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    key = jax.random.PRNGKey(steps)
    for i in range(steps):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (4,))}
        new, state = opt.update(g, state, params)
        assert float(jnp.abs(new["w"] - params["w"]).max()) <= lr * 1.2
        params = new


def test_schedules():
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.array(0))) == 0.0
    assert float(wc(jnp.array(10))) == pytest.approx(1.0)
    assert float(wc(jnp.array(100))) == pytest.approx(0.1, abs=1e-3)
    isq = inverse_sqrt(1.0, 100)
    assert float(isq(jnp.array(400))) == pytest.approx(0.5)
    assert float(constant(0.3)(jnp.array(7))) == pytest.approx(0.3)


def test_adafactor_memory_is_factored():
    opt = adafactor(0.01)
    params = {"w": jnp.zeros((128, 256))}
    state = opt.init(params)
    slots = state["slots"]["w"]
    n_slot = sum(x.size for x in jax.tree.leaves(slots))
    assert n_slot == 128 + 256          # vr + vc, not 128*256
