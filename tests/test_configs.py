"""Config registry: exact assigned dims, smoke-variant invariants."""
import pytest

from repro.configs import ASSIGNED_ARCHS, available_archs, get_config
from repro.configs.shapes import SHAPES, get_shape

# the assignment table, verbatim
ASSIGNED_DIMS = {
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
}


def test_all_assigned_archs_registered():
    avail = available_archs()
    for a in ASSIGNED_ARCHS:
        assert a in avail


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_assigned_dimensions(arch):
    L, d, H, kv, ff, V = ASSIGNED_DIMS[arch]
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), arch


def test_special_features():
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("qwen3-moe-235b-a22b").moe.n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.experts_per_token == 8
    assert get_config("deepseek-moe-16b").moe.n_shared_experts == 2
    assert get_config("deepseek-moe-16b").moe.experts_per_token == 6
    assert get_config("gemma-7b").resolved_head_dim == 256
    assert get_config("starcoder2-7b").sliding_window == 4096
    assert get_config("llava-next-mistral-7b").frontend.n_tokens == 2880
    assert get_config("whisper-base").encoder_positions == 1500
    assert get_config("xlstm-1.3b").xlstm.slstm_every == 8


def test_shapes_exact():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) \
        == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len,
            SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len,
            SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len,
            SHAPES["long_500k"].global_batch) == (524288, 1)
    assert SHAPES["decode_32k"].is_decode
    with pytest.raises(KeyError):
        get_shape("nope")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_variant_preserves_family_and_ratio(arch):
    c = get_config(arch)
    s = c.smoke_variant()
    assert s.family == c.family
    assert s.block_layout()[0].split("+")[0] == \
        c.block_layout()[0].split("+")[0]
    if c.n_kv_heads < c.n_heads:
        assert s.n_kv_heads < s.n_heads      # GQA ratio preserved in kind
    s.validate()


def test_smoke_variant_property_sweep():
    """Hypothesis property sweep; skips when the dev extra isn't installed
    (the baked container image has no hypothesis — CI installs it)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(arch=st.sampled_from(ASSIGNED_ARCHS))
    def check(arch):
        c = get_config(arch)
        s = c.smoke_variant()
        s.validate()
        assert s.n_layers <= c.n_layers
        assert s.d_model <= c.d_model

    check()


def test_long_context_policy():
    from repro.launch.specs import combo_supported
    shape = SHAPES["long_500k"]
    skipped = [a for a in ASSIGNED_ARCHS
               if not combo_supported(get_config(a), shape)[0]]
    assert skipped == ["whisper-base"]
