"""Transfer service: the paper's linear model + Fig-3 concurrency curve."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import build_system
from repro.core.facility import paper_topology
from repro.core.transfer import FileRef


def test_linear_model_components():
    sys_ = build_system()
    # T = x/v + S: doubling bytes roughly doubles the bandwidth part
    t1 = sys_.transfer.duration_model("slac", "alcf", 10**9, 1)
    t2 = sys_.transfer.duration_model("slac", "alcf", 2 * 10**9, 1)
    link = sys_.topo.link("slac", "alcf")
    v = link.effective_rate(1)
    assert abs((t2 - t1) - 10**9 / v) < 1e-6


@settings(max_examples=25, deadline=None)
@given(c1=st.integers(1, 16), c2=st.integers(1, 16))
def test_throughput_monotonic_in_concurrency(c1, c2):
    """Fig. 3 property: more concurrency never reduces effective rate."""
    link = paper_topology().link("slac", "alcf")
    lo, hi = min(c1, c2), max(c1, c2)
    assert link.effective_rate(lo) <= link.effective_rate(hi) + 1e-9


def test_fig3_saturates_above_1GBps():
    """Paper: 'more than 1 GB/s when transferring multiple files'."""
    link = paper_topology().link("slac", "alcf")
    assert link.effective_rate(16) > 1e9
    assert link.effective_rate(1) < 0.5e9


def test_transfer_moves_payload_and_charges_clock():
    sys_ = build_system()
    sys_.store.put("slac", FileRef("a", 100_000_000, payload=b"x"))
    t0 = sys_.clock.now
    rec = sys_.transfer.submit("slac", "alcf", ["a"])
    assert sys_.store.exists("alcf", "a")
    assert sys_.store.get("alcf", "a").payload == b"x"
    assert sys_.clock.now - t0 == pytest.approx(rec.duration)


def test_fault_injection_retries_and_still_delivers():
    sys_ = build_system(fault_rate=0.5, seed=42)
    sys_.store.put("slac", FileRef("a", 50_000_000))
    recs = [sys_.transfer.submit("slac", "alcf", ["a"]) for _ in range(10)]
    assert any(r.retries > 0 for r in recs)     # faults occurred
    assert all(r.duration > 0 for r in recs)    # and were recovered
    clean = build_system(fault_rate=0.0)
    clean.store.put("slac", FileRef("a", 50_000_000))
    base = clean.transfer.submit("slac", "alcf", ["a"])
    retried = [r for r in recs if r.retries > 0]
    assert all(r.duration > base.duration for r in retried)


def test_intra_facility_transfer_is_cheap():
    sys_ = build_system()
    sys_.store.put("slac", FileRef("a", 10**9))
    rec = sys_.transfer.submit("slac", "slac", ["a"])
    assert rec.duration < 0.5
