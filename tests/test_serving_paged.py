"""Paged serving correctness: paged decode vs teacher-forced forward, and
token-identical equivalence of the paged engine against the dense-slot
reference engine — with and without preemption pressure."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import DecodeEngine, PagedDecodeEngine, SlotDecodeEngine


def _api_params(key, arch="gemma-7b", **overrides):
    cfg = get_config(arch).smoke_variant()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    api = build_model(cfg)
    return cfg, api, api.init(key)


def _prompts(cfg, n, lo=3, hi=12, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
def test_paged_decode_matches_forward(key):
    """Feeding tokens one-by-one through paged_decode_step reproduces the
    teacher-forced forward logits — the paged analogue of the repo's
    decode-vs-forward consistency property."""
    cfg, api, params = _api_params(key)
    B, S, bs = 2, 16, 4
    max_blocks = S // bs
    num_blocks = B * max_blocks + 1
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    fwd_logits, _ = api.forward(params, tokens, compute_dtype=jnp.float32,
                                remat=False)

    cache = api.init_paged_cache(B, num_blocks=num_blocks, block_size=bs,
                                 max_blocks_per_lane=max_blocks,
                                 dtype=jnp.float32)
    # hand-build disjoint block tables: lane b owns blocks [1+b*m, ...]
    tables = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        tables[b] = 1 + b * max_blocks + np.arange(max_blocks)
    cache["block_tables"] = jnp.asarray(tables)

    dec = []
    for t in range(S):
        logits, cache = api.paged_decode_step(params, cache,
                                              tokens[:, t:t + 1],
                                              compute_dtype=jnp.float32)
        dec.append(logits[:, 0])
    dec = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd_logits),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
def test_paged_engine_token_identical_to_slot_engine(key):
    """More requests than lanes (slot reuse, staggered admissions): the
    paged engine and the dense-slot reference produce identical tokens."""
    cfg, api, params = _api_params(key)
    prompts = _prompts(cfg, 6)
    common = dict(n_slots=3, cache_len=64, cache_dtype=jnp.float32,
                  compute_dtype=jnp.float32)

    pe = DecodeEngine(api, params, **common)
    assert isinstance(pe, PagedDecodeEngine)   # transformer family -> paged
    se = DecodeEngine(api, params, paged=False, **common)
    assert isinstance(se, SlotDecodeEngine)
    for p in prompts:
        pe.submit(p, 8)
        se.submit(p, 8)
    done_p = {r.request_id: r.generated for r in pe.run_until_drained()}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert len(done_p) == len(prompts)
    assert done_p == done_s


def test_paged_engine_preemption_is_token_identical(key):
    """A pool too small for all lanes forces preemption-by-recompute; the
    outputs must not change."""
    cfg, api, params = _api_params(key)
    prompts = _prompts(cfg, 6)
    common = dict(n_slots=3, cache_len=64, block_size=4,
                  cache_dtype=jnp.float32, compute_dtype=jnp.float32)

    free_run = PagedDecodeEngine(api, params, **common)
    tight = PagedDecodeEngine(api, params, num_blocks=9, **common)
    for p in prompts:
        free_run.submit(p, 8)
        tight.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    got = {r.request_id: r.generated for r in tight.run_until_drained()}
    assert tight.scheduler.total_preemptions > 0
    assert free_run.scheduler.total_preemptions == 0
    assert got == ref


# ---------------------------------------------------------------------------
def test_paged_admits_more_lanes_at_equal_memory(key):
    """The headline memory win: at the same physical KV budget, the paged
    engine serves more concurrent requests than dense per-lane slabs."""
    cfg, api, params = _api_params(key)
    cache_len, bs = 64, 8
    dense_lanes = 2
    pool_tokens = dense_lanes * cache_len          # dense budget: 128 tokens
    # short requests (<= 16 tokens each): paged fits 8 lanes in that budget
    paged_lanes = 8
    eng = PagedDecodeEngine(api, params, n_slots=paged_lanes,
                            cache_len=cache_len, block_size=bs,
                            num_blocks=pool_tokens // bs + 1,
                            cache_dtype=jnp.float32,
                            compute_dtype=jnp.float32)
    for p in _prompts(cfg, paged_lanes, lo=4, hi=8):
        eng.submit(p, 8)
    peak_active = 0
    while eng.scheduler.has_work():
        eng.step()
        peak_active = max(peak_active, len(eng.scheduler.running))
    assert peak_active > dense_lanes               # strictly higher concurrency
    assert eng.scheduler.total_preemptions == 0
    assert eng.tokens_decoded == 8 * paged_lanes


def test_paged_engine_rejects_oversized_request(key):
    cfg, api, params = _api_params(key)
    eng = PagedDecodeEngine(api, params, n_slots=2, cache_len=32,
                            block_size=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(30, np.int32), 8)      # 38 > cache_len


def test_slot_engine_lane_reuse_no_stale_kv(key):
    """Regression for the dense engine's slot-recycling: a request admitted
    into a reused lane must match the same request run alone."""
    cfg, api, params = _api_params(key)
    prompts = _prompts(cfg, 3, seed=7)
    eng = SlotDecodeEngine(api, params, n_slots=1, cache_len=64,
                           cache_dtype=jnp.float32,
                           compute_dtype=jnp.float32)
    for p in prompts:
        eng.submit(p, 6)
    shared = {r.request_id: r.generated for r in eng.run_until_drained()}
    for rid, p in enumerate(prompts):
        solo = SlotDecodeEngine(api, params, n_slots=1, cache_len=64,
                                cache_dtype=jnp.float32,
                                compute_dtype=jnp.float32)
        solo.submit(p, 6)
        (done,) = solo.run_until_drained()
        assert shared[rid] == done.generated, rid


# ---------------------------------------------------------------------------
# unified chunked step: prefill chunks + prefix sharing + copy-on-write
# ---------------------------------------------------------------------------
def test_chunked_paged_step_matches_forward(key):
    """Feeding the prompt through paged_step in multi-token chunks (the
    unified prefill/decode path) reproduces the teacher-forced forward
    logits at every position."""
    cfg, api, params = _api_params(key)
    B, S, bs, C = 2, 16, 4, 4
    max_blocks = S // bs
    num_blocks = B * max_blocks + 1
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    fwd_logits, _ = api.forward(params, tokens, compute_dtype=jnp.float32,
                                remat=False)

    cache = api.init_paged_cache(B, num_blocks=num_blocks, block_size=bs,
                                 max_blocks_per_lane=max_blocks,
                                 dtype=jnp.float32)
    tables = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        tables[b] = 1 + b * max_blocks + np.arange(max_blocks)
    cache["block_tables"] = jnp.asarray(tables)

    dec = []
    for t in range(0, S, C):
        logits, cache = api.paged_step(params, cache, tokens[:, t:t + C],
                                       compute_dtype=jnp.float32)
        dec.append(logits)
    dec = jnp.concatenate(dec, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd_logits),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("chunk,prefix", [(1, False), (5, True), (16, True)])
def test_chunked_engine_token_identical_to_slot_engine(key, chunk, prefix):
    """Chunked prefill at several chunk widths (1 = the PR 1 step shape),
    with and without prefix sharing, stays token-identical to the dense
    reference."""
    cfg, api, params = _api_params(key)
    prompts = _prompts(cfg, 6, lo=3, hi=14, seed=3)
    common = dict(n_slots=3, cache_len=64, cache_dtype=jnp.float32,
                  compute_dtype=jnp.float32)
    pe = PagedDecodeEngine(api, params, chunk_tokens=chunk,
                           prefix_cache=prefix, block_size=4, **common)
    se = SlotDecodeEngine(api, params, **common)
    for p in prompts:
        pe.submit(p, 8)
        se.submit(p, 8)
    done_p = {r.request_id: r.generated for r in pe.run_until_drained()}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert done_p == done_s and len(done_p) == len(prompts)
    if chunk > 1:
        # chunked prefill must actually shrink the step count: every prompt
        # token no longer costs one engine step
        assert pe.steps < se.steps


def test_prefix_sharing_cow_divergence_token_identical(key):
    """Two requests with an identical block-aligned prompt: the second
    admission forks the cached prefix blocks and its first divergent write
    (re-processing the last prompt token for logits) copy-on-writes the
    shared tail block.  Outputs must match the dense reference exactly."""
    cfg, api, params = _api_params(key)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)  # 2 blocks
    common = dict(n_slots=1, cache_len=64, cache_dtype=jnp.float32,
                  compute_dtype=jnp.float32)
    pe = PagedDecodeEngine(api, params, block_size=4, chunk_tokens=8,
                           prefix_cache=True, **common)
    se = SlotDecodeEngine(api, params, **common)
    for _ in range(2):                      # serial: n_slots=1
        pe.submit(prompt, 6)
        se.submit(prompt, 6)
    done_p = {r.request_id: r.generated for r in pe.run_until_drained()}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert done_p == done_s
    assert done_p[0] == done_p[1]           # greedy: identical continuations
    st = pe.stats()
    assert st["prefix_hits"] >= 1
    assert st["prefix_tokens_reused"] >= 7  # all but the re-processed token
    assert st["cow_copies"] >= 1            # shared tail block was forked
    assert pe.cow_block_copies >= 1         # and the device copy was applied


def test_prefix_sharing_skips_prefill_steps(key):
    """A shared system prompt must make later requests' prefill nearly
    free: with the cache on, request 2..N admit at cursor ~= prompt end."""
    cfg, api, params = _api_params(key)
    rng = np.random.default_rng(5)
    system = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [np.concatenate([system,
                               rng.integers(0, cfg.vocab_size, 3)
                               .astype(np.int32)]) for _ in range(4)]
    common = dict(n_slots=1, cache_len=64, block_size=4, chunk_tokens=8,
                  cache_dtype=jnp.float32, compute_dtype=jnp.float32)
    on = PagedDecodeEngine(api, params, prefix_cache=True, **common)
    off = PagedDecodeEngine(api, params, prefix_cache=False, **common)
    for p in prompts:
        on.submit(p, 4)
        off.submit(p, 4)
    done_on = {r.request_id: r.generated for r in on.run_until_drained()}
    done_off = {r.request_id: r.generated for r in off.run_until_drained()}
    assert done_on == done_off
    assert on.stats()["prefix_tokens_reused"] >= 3 * 24
    assert on.steps < off.steps
    assert on.tokens_prefilled < off.tokens_prefilled


def test_preemption_with_chunked_prefill_token_identical(key):
    """Preemption pressure with multi-token chunks in flight (mid-chunk
    truncation + replay) must not change any output."""
    cfg, api, params = _api_params(key)
    prompts = _prompts(cfg, 6, lo=6, hi=14, seed=9)
    common = dict(n_slots=3, cache_len=64, block_size=4, chunk_tokens=6,
                  cache_dtype=jnp.float32, compute_dtype=jnp.float32)
    free_run = PagedDecodeEngine(api, params, **common)
    tight = PagedDecodeEngine(api, params, num_blocks=10, **common)
    for p in prompts:
        free_run.submit(p, 8)
        tight.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    got = {r.request_id: r.generated for r in tight.run_until_drained()}
    assert tight.scheduler.total_preemptions > 0
    assert got == ref
