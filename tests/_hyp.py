"""Optional-hypothesis shim for the property tests.

The serving container bakes in jax but not hypothesis; CI installs it via
the ``dev`` extra and runs the full property sweep.  Importing from this
module instead of ``hypothesis`` directly keeps ``pytest -x -q`` green out
of the box: without hypothesis every ``@given`` test is collected as a
plain skip.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dev extra
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (strategies are only inspected by @given)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def _skip():
                pytest.skip("hypothesis not installed (pip install -e .[dev])")

            _skip.__name__ = fn.__name__
            _skip.__doc__ = fn.__doc__
            return _skip

        return deco
