"""Checkpoint roundtrip, integrity, retention."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"layer": {"w": jax.random.normal(k1, (8, 16)),
                      "b": jnp.zeros((16,), jnp.bfloat16)},
            "step": jnp.array(7, jnp.int32),
            "stack": jax.random.normal(k2, (3, 4, 5))}


def test_roundtrip(tmp_path, key):
    tree = _tree(key)
    ck.save_checkpoint(str(tmp_path), 5, tree)
    restored, manifest = ck.restore_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_retention(tmp_path, key):
    tree = _tree(key)
    for step in (1, 2, 3, 4, 5):
        ck.save_checkpoint(str(tmp_path), step, tree, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".json"))
    assert len(kept) == 2


def test_shape_mismatch_rejected(tmp_path, key):
    tree = _tree(key)
    ck.save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree, stack=jnp.zeros((9, 9)))
    with pytest.raises((ValueError, KeyError)):
        ck.restore_checkpoint(str(tmp_path), bad)


def test_corruption_detected(tmp_path, key):
    tree = _tree(key)
    base = ck.save_checkpoint(str(tmp_path), 1, tree)
    data = dict(np.load(base + ".npz"))
    data["a0"] = data["a0"] + 1.0       # corrupt one array
    np.savez(base + ".npz", **data)
    with pytest.raises(IOError):
        ck.restore_checkpoint(str(tmp_path), tree)
