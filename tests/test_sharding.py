"""Sharding rules: divisibility safety (property) + intent checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import sharding as sh
from repro.launch import specs as specs_lib
from repro.models import build_model

AXES = {"data": 16, "model": 16}
AXES_MP = {"pod": 2, "data": 16, "model": 16}


def _axis_product(spec_entry, axes):
    if spec_entry is None:
        return 1
    if isinstance(spec_entry, tuple):
        n = 1
        for a in spec_entry:
            n *= axes[a]
        return n
    return axes[spec_entry]


@settings(max_examples=40, deadline=None)
@given(
    name=st.sampled_from(["wq", "wk", "wo", "w_up", "w_down",
                          "experts_w_gate", "embedding", "router",
                          "conv_w", "r_gates", "anything_else"]),
    dims=st.lists(st.sampled_from([1, 3, 4, 7, 16, 48, 128, 256, 1000]),
                  min_size=1, max_size=4),
)
def test_spec_always_divisible(name, dims):
    """For ANY leaf name and shape, the generated spec divides the shape."""
    spec = sh.spec_for_leaf(f"blocks/attn/{name}", tuple(dims), AXES)
    assert len(spec) == len(dims)
    for d, s in zip(dims, spec):
        assert d % _axis_product(s, AXES) == 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_full_tree(arch):
    cfg = get_config(arch)
    api = build_model(cfg)
    tree = specs_lib.abstract_params(api)
    specs = sh.param_specs(tree, AXES_MP, data_axes=("pod", "data"))
    flat_t = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_t) == len(flat_s)
    for leaf, spec in zip(flat_t, flat_s):
        for d, s in zip(leaf.shape, spec):
            assert d % _axis_product(s, AXES_MP) == 0, (arch, leaf.shape,
                                                        spec)


def test_big_weights_actually_sharded():
    """The dominant tensors must not silently replicate."""
    cfg = get_config("qwen3-moe-235b-a22b")
    api = build_model(cfg)
    tree = specs_lib.abstract_params(api)
    specs = sh.param_specs(tree, AXES)
    blocks = specs["blocks"]
    # experts (L, E, d, h): expert dim on model, d on data
    assert blocks["moe"]["experts_w_gate"] == P(None, "model", "data", None)
    assert blocks["moe"]["experts_w_down"] == P(None, "model", None, "data")
    assert specs["embed"]["embedding"] == P("model", "data")


def test_batch_spec_degrades_for_small_batches():
    assert sh.batch_spec((256, 4096), AXES) == P("data", None)
    assert sh.batch_spec((256, 4096), AXES_MP,
                         data_axes=("pod", "data")) == P(("pod", "data"),
                                                         None)
    # B=1 (long_500k): replicate, never crash
    assert sh.batch_spec((1, 9), AXES) == P(None, None)
    # B=8: fits neither 32 nor 16 -> replicated on multi-pod data axes?
    spec = sh.batch_spec((8, 4), AXES_MP, data_axes=("pod", "data"))
    for d, s in zip((8, 4), spec):
        assert d % _axis_product(s, AXES_MP) == 0


def test_cache_specs_shard_slots_and_heads():
    cfg = get_config("llava-next-mistral-7b")
    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(128, 32768))
    specs = sh.cache_specs(cache, AXES)
    kspec = specs["scan"]["k"]          # (L, B, S, Hkv, D)
    shape = cache["scan"]["k"].shape
    for d, s in zip(shape, kspec):
        assert d % _axis_product(s, AXES) == 0
    assert any(s is not None for s in kspec)    # not fully replicated
    # int bookkeeping replicated
    assert all(s is None for s in specs["slot_positions"])
