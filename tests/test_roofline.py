"""Roofline machinery: analytic accounting vs compiled cost_analysis on
loop-free configs; HLO collective parser; term arithmetic."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.shapes import InputShape
from repro.launch import specs as specs_lib
from repro.launch.dryrun import moe_active_params
from repro.models import build_model
from repro.roofline import analytic, hlo_parse
from repro.roofline.analysis import RooflineTerms


# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arch,tol", [
    ("starcoder2-7b", 0.10), ("gemma-7b", 0.10),
    ("qwen3-moe-235b-a22b", 0.25),
])
def test_analytic_flops_match_compiled_loop_free(arch, tol):
    """1-layer, short-seq (full attention), no-remat configs have no loops,
    so cost_analysis is trustworthy there — analytic must agree."""
    cfg0 = get_config(arch)
    cfg = dataclasses.replace(cfg0, n_layers=1, vocab_size=2048)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, first_dense_layers=0))
    shape = InputShape("tiny_train", 256, 2, "train")
    api = build_model(cfg)
    params_sds = specs_lib.abstract_params(api)
    step, opt = specs_lib.make_train_step_fn(api, shape, remat=False)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = specs_lib.batch_abstract(cfg, shape)
    compiled = jax.jit(step).lower(params_sds, opt_sds, batch_sds).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # jax < 0.5: one dict per device
        ca = ca[0]
    flops_hlo = ca["flops"]

    n_tot = sum(int(l.size) for l in jax.tree.leaves(params_sds))
    n_act = moe_active_params(cfg, params_sds)
    acct = analytic.step_account(cfg, shape, window=0, n_params_total=n_tot,
                                 n_params_active=n_act, remat=False)
    rel = abs(acct["flops"] - flops_hlo) / flops_hlo
    assert rel < tol, (arch, acct["flops"], flops_hlo)


# ---------------------------------------------------------------------------
HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(f32[16,64]{1,0} %z), dimensions={0}
  %a2a = (bf16[4,4]{1,0}) all-to-all(bf16[4,4]{1,0} %w)
  %cp = u32[10]{0} collective-permute(u32[10]{0} %v)
}
"""


def test_collective_parser_counts_and_bytes():
    info = hlo_parse.collective_bytes(HLO_SAMPLE)
    assert info["all-gather"]["count"] == 1
    assert info["all-gather"]["bytes"] == 8 * 128 * 2
    assert info["all-reduce"]["bytes"] == 256 * 4
    assert info["reduce-scatter"]["bytes"] == 2 * 64 * 4
    assert info["all-to-all"]["bytes"] == 4 * 4 * 2
    assert info["collective-permute"]["bytes"] == 10 * 4
    total = hlo_parse.total_collective_bytes(HLO_SAMPLE)
    assert total == sum(v["bytes"] for v in info.values())


def test_roofline_term_arithmetic():
    t = RooflineTerms(arch="a", shape="s", mesh="m", n_chips=256,
                      hlo_flops=256 * 197e12,      # exactly 1s of compute
                      hlo_bytes=256 * 819e9 * 0.5,  # 0.5s of HBM
                      collective_bytes_per_dev=50e9 * 0.25,  # 0.25s of ICI
                      model_flops=256 * 197e12 * 0.6)
    assert t.compute_term == pytest.approx(1.0)
    assert t.memory_term == pytest.approx(0.5)
    assert t.collective_term == pytest.approx(0.25)
    assert t.dominant == "compute"
    assert t.mfu_upper_bound == pytest.approx(0.6)
    assert t.useful_flops_ratio == pytest.approx(0.6)
