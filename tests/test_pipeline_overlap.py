"""Paper future-work #3: A||T overlap — cost model + real pipelined run."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import build_system
from repro.core.pipeline_flow import run_overlapped_label_train
from repro.core.transfer import FileRef


def test_costmodel_pipelined_beats_serial():
    cm = build_system().costmodel
    n = 10**8
    serial = cm.f_ml(n, p=0.1)
    pipe = cm.f_ml_pipelined(n, p=0.1)
    assert pipe.total < serial.total
    # saving is bounded by min(label, train)
    label = serial.breakdown["label"]
    train = serial.breakdown["train"]
    assert serial.total - pipe.total <= min(label, train) + 1e-6


def test_costmodel_pipelined_converges_to_max():
    cm = build_system().costmodel
    n = 10**8
    a = cm.f_ml_pipelined(n, p=0.1, n_microbatches=10**6)
    serial = cm.f_ml(n, p=0.1)
    label = serial.breakdown["label"]
    train = serial.breakdown["train"]
    expect = serial.total - (label + train) + max(label, train)
    assert a.total == pytest.approx(expect, rel=1e-3)


def test_real_overlapped_pipeline_trains_and_saves_time(key):
    from repro.analysis import label_for_braggnn
    from repro.configs import BraggNNConfig
    from repro.data.synthetic import bragg_patches
    from repro.models import braggnn
    from repro.optim import adam

    sys_ = build_system()
    cfg = BraggNNConfig()
    d = bragg_patches(key, 512)
    sys_.store.put("alcf", FileRef("scan.h5", 1, payload={
        "patches": d["patches"]}))

    opt = adam(1e-3)

    def train_init():
        params = braggnn.init_params(key, cfg)
        return {"params": params, "opt": opt.init(params)}

    @jax.jit
    def _step(params, opt_state, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: braggnn.loss_fn(p, batch, cfg), has_aux=True)(params)
        p2, o2 = opt.update(g, opt_state, params)
        return p2, o2, l

    def train_shard(state, shard, labels):
        p, o, l = _step(state["params"], state["opt"],
                        {"patches": shard["patches"], "centers": labels})
        return {"params": p, "opt": o}, {"loss": float(l)}

    res = run_overlapped_label_train(
        sys_, dataset_facility="alcf", dataset_name="scan.h5",
        label_fn=lambda s: label_for_braggnn(s["patches"]),
        train_init_fn=train_init, train_shard_fn=train_shard, n_shards=4)

    assert res["metrics"]["loss"] > 0
    assert res["pipelined_s"] < res["serial_s"]
    assert res["saving_s"] > 0
    assert sys_.store.exists("alcf", "model.npz")
    # the clock was charged the pipelined time, not the serial time
    assert sys_.clock.breakdown()["real"] == pytest.approx(
        res["pipelined_s"], rel=1e-6)


def test_data_repository_augmentation():
    """Future-work #2: prior labeled datasets augment a new experiment."""
    from repro.core.registry import DataRepository

    repo = DataRepository()
    repo.register("hedm-ni-alloy", FileRef("scan1", 1000),
                  metadata={"detector": "GE", "energy_kev": 80})
    repo.register("hedm-ni-alloy", FileRef("scan2", 2000),
                  metadata={"detector": "GE", "energy_kev": 60})
    repo.register("hedm-ni-alloy", FileRef("scan3-raw", 4000), labeled=False)
    repo.register("ptycho", FileRef("other", 9000))

    all_labeled = repo.augment_for("hedm-ni-alloy")
    assert [e["artifact"].name for e in all_labeled] == ["scan1", "scan2"]
    ge80 = repo.augment_for("hedm-ni-alloy", match={"energy_kev": 80})
    assert len(ge80) == 1 and ge80[0]["artifact"].name == "scan1"
    with_raw = repo.augment_for("hedm-ni-alloy", labeled_only=False)
    assert len(with_raw) == 3
    assert repo.total_bytes("hedm-ni-alloy") == 7000


def test_overlap_as_flow_action(key):
    """The A||T overlap runs as a first-class Flows action provider."""
    import jax
    from repro.analysis import label_for_braggnn
    from repro.configs import BraggNNConfig
    from repro.data.synthetic import bragg_patches
    from repro.models import braggnn
    from repro.optim import adam

    sys_ = build_system()
    tok = sys_.user_token()
    cfg = BraggNNConfig()
    d = bragg_patches(key, 256)
    sys_.store.put("alcf", FileRef("scan.h5", 1,
                                   payload={"patches": d["patches"]}))

    opt = adam(1e-3)
    lid = sys_.funcx.register_function(
        lambda s: label_for_braggnn(s["patches"]), "label")
    iid = sys_.funcx.register_function(
        lambda: {"params": braggnn.init_params(jax.random.PRNGKey(0), cfg),
                 "opt": opt.init(braggnn.init_params(
                     jax.random.PRNGKey(0), cfg))}, "init")

    def shard_step(state, shard, labels):
        (l, _), g = jax.value_and_grad(
            lambda p: braggnn.loss_fn(
                p, {"patches": shard["patches"], "centers": labels}, cfg),
            has_aux=True)(state["params"])
        p2, o2 = opt.update(g, state["opt"], state["params"])
        return {"params": p2, "opt": o2}, {"loss": float(l)}

    sid = sys_.funcx.register_function(shard_step, "shard")

    flow_id = sys_.flows.deploy({
        "StartAt": "OverlapTrain",
        "States": {
            "OverlapTrain": {
                "Provider": "overlap_label_train",
                "Parameters": {
                    "facility": "alcf", "dataset_name": "scan.h5",
                    "label_function": lid,
                    "train_init_function": iid,
                    "train_shard_function": sid,
                    "n_shards": 4, "artifact_name": "m.npz",
                },
                "End": True,
            },
        },
    })
    run = sys_.flows.run(flow_id, {}, tok)
    assert run.status == "SUCCEEDED", run.log[0].error
    out = run.output["OverlapTrain"]
    assert out["saving_s"] > 0
    assert out["pipelined_s"] < out["serial_s"]
    assert sys_.store.exists("alcf", "m.npz")
