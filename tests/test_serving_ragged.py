"""Ragged flat-token serving batch: differential correctness harness.

The ragged engine (one 1-D stream of all scheduled tokens per step, no
``(lanes, chunk_width)`` rectangle) must be **token-identical** to both the
dense-slot reference engine and the rectangular paged engine under every
combination of arrival schedule, prompt lengths, token budgets, chunk
widths, preemption pressure, prefix sharing, and **speculative decode**
(``spec``/``draft_k`` are fuzz dimensions: n-gram drafts verified by the
step's own argmax, with KV rewind of rejected slots) — in both attention
grids: the default **segment-tiled** grid (KV swept once per q-tile) and
the per-token baseline (``tiled=False``).  The hypothesis fuzz test
drives randomized workloads end-to-end through both engines; the plain
tests pin the named regressions, including the speculative accept corners
(all-accept, all-reject, partial accept straddling a block boundary).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (DecodeEngine, PagedDecodeEngine, Proposer,
                           RaggedBatch, SlotDecodeEngine)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(cfg, n, lo=3, hi=12, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


COMMON = dict(cache_len=64, cache_dtype=jnp.float32,
              compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# pinned differential regressions
# ---------------------------------------------------------------------------
def test_ragged_is_default_paged_layout(model):
    cfg, api, params = model
    eng = DecodeEngine(api, params, n_slots=2, **COMMON)
    assert isinstance(eng, PagedDecodeEngine) and eng.ragged
    assert eng.tiled                 # segment-tiled grid is the default
    assert eng.spec                  # speculative decode is the default
    rect = PagedDecodeEngine(api, params, n_slots=2, ragged=False, **COMMON)
    assert not rect.ragged and not rect.tiled
    pertok = PagedDecodeEngine(api, params, n_slots=2, tiled=False, **COMMON)
    assert pertok.ragged and not pertok.tiled
    with pytest.raises(ValueError):  # tiling needs the flat stream
        PagedDecodeEngine(api, params, n_slots=2, ragged=False, tiled=True,
                          **COMMON)
    nospec = PagedDecodeEngine(api, params, n_slots=2, draft_k=0, **COMMON)
    assert not nospec.spec           # draft_k=0 pins plain decode too


def test_ragged_engine_token_identical_to_slot_engine(model):
    """The archetype core: ragged flat-token engine vs the dense-slot
    oracle, more requests than lanes (staggered admissions, lane reuse)."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6)
    re = PagedDecodeEngine(api, params, n_slots=3, **COMMON)
    se = SlotDecodeEngine(api, params, n_slots=3, **COMMON)
    assert re.ragged
    for p in prompts:
        re.submit(p, 8)
        se.submit(p, 8)
    done_r = {r.request_id: r.generated for r in re.run_until_drained()}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert len(done_r) == len(prompts)
    assert done_r == done_s


def test_ragged_engine_token_identical_to_rect_engine(model):
    """Direct layout differential: the flat stream vs the rectangular
    (lanes, width) batch over the same scheduler knobs."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=4, hi=14, seed=5)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=6, **COMMON)
    re = PagedDecodeEngine(api, params, ragged=True, **kw)
    rc = PagedDecodeEngine(api, params, ragged=False, **kw)
    for p in prompts:
        re.submit(p, 8)
        rc.submit(p, 8)
    done_r = {r.request_id: r.generated for r in re.run_until_drained()}
    done_c = {r.request_id: r.generated for r in rc.run_until_drained()}
    assert done_r == done_c and len(done_r) == len(prompts)


def test_tiled_engine_token_identical_to_per_token_engine(model):
    """Direct attention-grid differential: the segment-tiled sweep vs the
    per-token (token, head, block) baseline over the same flat batches,
    with tile widths bigger and smaller than the prefill chunks."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=4, hi=14, seed=17)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=6, **COMMON)
    for tile in (4, 16):
        te = PagedDecodeEngine(api, params, tiled=True, tile=tile, **kw)
        pe = PagedDecodeEngine(api, params, tiled=False, **kw)
        for p in prompts:
            te.submit(p, 8)
            pe.submit(p, 8)
        done_t = {r.request_id: r.generated for r in te.run_until_drained()}
        done_p = {r.request_id: r.generated for r in pe.run_until_drained()}
        assert done_t == done_p and len(done_t) == len(prompts)


def test_ragged_preemption_token_identical(model):
    """A pool too small for all lanes forces preemption-by-recompute with
    flat batches in flight; outputs must not change."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=6, hi=14, seed=9)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=6, **COMMON)
    free_run = PagedDecodeEngine(api, params, **kw)
    tight = PagedDecodeEngine(api, params, num_blocks=10, **kw)
    for p in prompts:
        free_run.submit(p, 8)
        tight.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    got = {r.request_id: r.generated for r in tight.run_until_drained()}
    assert tight.scheduler.total_preemptions > 0
    assert got == ref


def test_ragged_prefix_sharing_cow_token_identical(model):
    """CoW prefix sharing under the flat layout: identical prompts fork
    cached blocks; outputs must match the dense reference exactly."""
    cfg, api, params = model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    re = PagedDecodeEngine(api, params, n_slots=1, block_size=4,
                           chunk_tokens=8, prefix_cache=True, **COMMON)
    se = SlotDecodeEngine(api, params, n_slots=1, **COMMON)
    for _ in range(2):
        re.submit(prompt, 6)
        se.submit(prompt, 6)
    done_r = {r.request_id: r.generated for r in re.run_until_drained()}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert done_r == done_s
    assert re.stats()["prefix_hits"] >= 1
    assert re.cow_block_copies >= 1


def test_ragged_padding_efficiency_beats_rect_on_mixed_load(model):
    """The point of the layout: on a mixed prefill+decode load the flat
    stream wastes (far) fewer padded slots than the rectangle."""
    cfg, api, params = model
    prompts = _prompts(cfg, 8, lo=8, hi=16, seed=13)
    kw = dict(n_slots=4, block_size=4, chunk_tokens=8, **COMMON)
    re = PagedDecodeEngine(api, params, ragged=True, **kw)
    rc = PagedDecodeEngine(api, params, ragged=False, **kw)
    # staggered arrival: prefill chunks and decodes coexist in most steps
    pending_r, pending_c = list(prompts), list(prompts)
    while pending_r or re.scheduler.has_work():
        if pending_r:
            re.submit(pending_r.pop(0), 8)
        re.step()
    while pending_c or rc.scheduler.has_work():
        if pending_c:
            rc.submit(pending_c.pop(0), 8)
        rc.step()
    eff_r = re.stats()["padding_efficiency"]
    eff_c = rc.stats()["padding_efficiency"]
    assert eff_r > eff_c
    assert eff_r >= 0.8


# ---------------------------------------------------------------------------
# the fuzz harness (hypothesis; collected as a skip without the dev extra)
# ---------------------------------------------------------------------------
def _drive_differential(model, seed, n_requests, n_slots, chunk_tokens,
                        token_budget, tight_pool, prefix, arrival_every,
                        tiled=True, tile=8, spec=False, draft_k=4,
                        mesh=False, tp=1, quantized=False, swap=True,
                        oversub=False, cancel=False):
    """One randomized workload through ragged-paged vs dense-slot engines,
    asserting token identity end-to-end (shared by the hypothesis fuzz and
    the pinned no-hypothesis cases).  ``tiled`` selects the attention
    grid: the segment-tiled sweep (default) or the per-token baseline;
    ``spec``/``draft_k`` turn on speculative multi-token decode (n-gram
    drafts + verification + KV rewind), which must never change a single
    output token.  ``mesh`` serves the paged side across every virtual
    device (``tp``-way tensor parallel, the rest data-parallel slices —
    a :class:`ShardedDecodeEngine` whenever more than one slice results);
    outputs must STILL match the single-device dense oracle exactly.

    Tiered-KV dimensions: ``quantized`` stores KV blocks as int8 with
    per-block scales (the oracle then becomes a roomy int8 paged engine
    on the OTHER attention grid — same quantized storage, different
    layout — because int8-vs-fp identity is empirical, not structural);
    ``swap`` toggles the device→host swap tier (on by default, matching
    the engine); ``oversub`` shrinks the pool to ~half the workload's
    total block demand, so survival requires swap or recompute.

    ``cancel`` fires random mid-flight aborts on the paged side only
    (the oracle never cancels): survivors must stay token-identical to
    the never-cancelled oracle run — cancellation of one request must
    not perturb any other — and after the drain no cancelled sequence
    may leave pending swap-ins behind."""
    cfg, api, params = model
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    prompts = []
    for _ in range(n_requests):
        body = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 12))).astype(np.int32)
        if prefix and rng.random() < 0.5:      # exercise the prefix cache
            body = np.concatenate([shared, body])
        prompts.append(body)
    max_new = [int(rng.integers(1, 7)) for _ in range(n_requests)]
    # pool sized to force preemption when tight (but never below one
    # request's worst-case footprint, which would be an unserveable config)
    worst = max(len(p) + m for p, m in zip(prompts, max_new))
    bs = 4
    max_blocks = -(-COMMON["cache_len"] // bs)
    need = -(-worst // bs)
    pool = (need + 2) if tight_pool else None
    if oversub:
        demand = sum(-(-(len(p) + m) // bs)
                     for p, m in zip(prompts, max_new))
        pool = max(need + 1, demand // 2)
    ekw = dict(n_slots=n_slots, block_size=bs, chunk_tokens=chunk_tokens,
               token_budget=token_budget, num_blocks=pool,
               prefix_cache=prefix, tiled=tiled, tile=tile,
               spec=spec, draft_k=draft_k, host_swap=swap, **COMMON)
    if quantized:
        ekw["cache_dtype"] = jnp.int8
    if mesh:
        from repro.launch.mesh import make_host_mesh
        ndev = len(jax.devices())
        tp_eff = tp if ndev % tp == 0 else 1
        re = DecodeEngine(api, params, paged=True,
                          mesh=make_host_mesh(model_parallel=tp_eff), **ekw)
        first = re.engines[0] if hasattr(re, "engines") else re
    else:
        re = PagedDecodeEngine(api, params, **ekw)
        first = re
    assert first.ragged and first.tiled == tiled and first.spec == spec
    assert first.host_swap == (swap and prefix)
    if quantized:
        okw = dict(COMMON, cache_dtype=jnp.int8)
        se = PagedDecodeEngine(api, params, n_slots=n_slots, block_size=bs,
                               chunk_tokens=chunk_tokens,
                               prefix_cache=False, tiled=not tiled,
                               tile=tile, spec=False, host_swap=False,
                               **okw)
    else:
        se = SlotDecodeEngine(api, params, n_slots=n_slots, **COMMON)
    assert first.max_blocks == max_blocks
    # seeded mid-flight abort schedule: ~40% of requests get one cancel
    # attempt at a random step (a late attempt may find the request
    # already finished — then it must complete token-identically)
    cancel_at = {rid: int(rng.integers(0, 15))
                 for rid in range(n_requests)
                 if cancel and rng.random() < 0.4}
    attempted: set = set()
    pending = list(zip(prompts, max_new))
    step = 0
    submitted = 0
    while pending or re.has_work():
        if pending and step % arrival_every == 0:
            p, m = pending.pop(0)
            re.submit(p, m)
            se.submit(p, m)
            submitted += 1
        for rid, at in cancel_at.items():
            if step >= at and rid < submitted and rid not in attempted:
                attempted.add(rid)
                re.cancel(rid)
        re.step()
        step += 1
        assert step < 2000, "ragged engine did not drain"
    fin_r = re.run_until_drained()
    cancelled = {r.request_id for r in fin_r if r.cancelled}
    done_r = {r.request_id: r.generated for r in fin_r if not r.cancelled}
    done_s = {r.request_id: r.generated for r in se.run_until_drained()}
    assert len(fin_r) == n_requests
    assert set(done_r) == set(range(n_requests)) - cancelled
    assert all(done_r[k] == done_s[k] for k in done_r)
    if cancel:
        # cancellation bookkeeping: no orphaned queued swap-ins, and no
        # sequence state left behind for any cancelled id
        for eng in (re.engines if hasattr(re, "engines") else [re]):
            assert not eng.kv.take_swap_ins()
            assert not eng.scheduler.running and not eng.scheduler.waiting


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n_requests=st.integers(1, 6),
    n_slots=st.integers(1, 3),
    chunk_tokens=st.sampled_from([1, 3, 8]),
    token_budget=st.sampled_from([0, 5, 16]),
    tight_pool=st.booleans(),
    prefix=st.booleans(),
    arrival_every=st.integers(1, 3),
    tiled=st.booleans(),
    tile=st.sampled_from([4, 8, 16]),
    spec=st.booleans(),
    draft_k=st.sampled_from([1, 2, 4]),
    mesh=st.booleans(),
    tp=st.sampled_from([1, 2]),
    quantized=st.booleans(),
    swap=st.booleans(),
    oversub=st.booleans(),
    cancel=st.booleans(),
)
def test_fuzz_ragged_vs_dense_token_identity(model, seed, n_requests,
                                             n_slots, chunk_tokens,
                                             token_budget, tight_pool,
                                             prefix, arrival_every,
                                             tiled, tile, spec, draft_k,
                                             mesh, tp, quantized, swap,
                                             oversub, cancel):
    """Differential fuzz: random arrival times / prompt lengths / budgets /
    preemption pressure / attention grid (segment-tiled vs per-token) /
    speculative decode (spec + draft_k) / mesh sharding (tp-way tensor
    parallel, data-parallel slicing across the rest of the virtual
    devices) / tiered KV (int8 block storage, host swap tier, pool
    oversubscription) / random mid-flight cancellation (survivors must
    match the never-cancelled oracle) driven through the ragged-paged
    engine vs the dense-slot oracle, asserting token identity
    end-to-end."""
    _drive_differential(model, seed, n_requests, n_slots, chunk_tokens,
                        token_budget, tight_pool, prefix, arrival_every,
                        tiled, tile, spec, draft_k, mesh, tp, quantized,
                        swap, oversub, cancel)


@pytest.mark.parametrize("case", [
    # seed, n_req, slots, chunk, budget, tight, prefix, arrival, tiled,
    # tile, spec, draft_k
    (3, 4, 2, 3, 5, True, False, 2, True, 4),   # tight pool + tiny budget
    (7, 5, 3, 8, 0, False, True, 1, True, 16),  # prefix sharing, burst
    (11, 3, 1, 1, 0, True, True, 3, True, 8),   # serial lane, 1-tok chunks
    (3, 4, 2, 3, 5, True, False, 2, False, 8),  # per-token grid baseline
    (7, 5, 3, 8, 0, False, True, 1, False, 8),  # per-token + prefix CoW
    # speculative decode rides every harness knob the baseline does
    (3, 4, 2, 3, 5, True, False, 2, True, 4, True, 4),   # spec + tight pool
    (7, 5, 3, 8, 0, False, True, 1, True, 16, True, 2),  # spec + prefix CoW
    (5, 4, 2, 8, 7, True, True, 2, True, 8, True, 4),    # spec + budget 7
    (9, 4, 2, 6, 0, False, False, 1, False, 8, True, 1), # spec, per-token
    # mesh-sharded serving: same oracle, + mesh/tp tail
    (3, 4, 2, 3, 5, True, False, 2, True, 4, False, 4, True, 2),   # dp x tp
    (7, 5, 3, 8, 0, False, True, 1, True, 16, True, 2, True, 4),   # pure tp
    (5, 4, 2, 8, 7, True, True, 2, True, 8, True, 4, True, 1),     # pure dp
    # tiered KV: int8 storage / host swap tier / pool oversubscription
    # (+ quantized, swap, oversub tail)
    (3, 4, 2, 3, 5, True, False, 2, True, 4, False, 4, False, 1,
     True),                                        # int8, tight pool
    (7, 5, 3, 8, 0, False, True, 1, True, 16, True, 2, False, 1,
     True, True),                                  # int8 + spec + swap
    (5, 4, 2, 8, 7, False, True, 2, True, 8, False, 4, False, 1,
     False, True, True),                           # swap under oversub
    (9, 5, 2, 6, 0, False, True, 1, True, 8, True, 4, False, 1,
     True, True, True),                            # int8 + swap + oversub
    (11, 4, 2, 3, 0, False, True, 2, False, 8, False, 4, False, 1,
     False, False, True),                          # oversub, recompute only
    # random mid-flight cancellation: survivors must match the
    # never-cancelled oracle (+ cancel tail)
    (3, 4, 2, 3, 5, True, False, 2, True, 4, False, 4, False, 1,
     False, True, False, True),                    # cancel + tight pool
    (7, 5, 3, 8, 0, False, True, 1, True, 16, True, 2, False, 1,
     False, True, False, True),                    # cancel + spec + prefix
    (5, 4, 2, 8, 7, False, True, 2, True, 8, False, 4, False, 1,
     False, True, True, True),                     # cancel under oversub
    (9, 5, 2, 6, 0, False, True, 1, True, 8, False, 4, True, 2,
     False, True, False, True),                    # cancel on the dp front
])
def test_differential_pinned_cases_token_identity(model, case):
    """The fuzz harness's named corners, runnable without hypothesis (the
    container lacks the dev extra; CI runs the full randomized sweep) —
    both attention grids and the speculative path ride through the same
    identity gate."""
    _drive_differential(model, *case)


# ---------------------------------------------------------------------------
# cancellation: pinned corners + the leak wall
# ---------------------------------------------------------------------------
def test_cancel_during_cow_shared_prefix_token_identical(model):
    """Cancel one of two requests sharing a CoW-forked prefix mid-flight:
    the survivor must keep its shared blocks (and its exact tokens), and
    the cancelled side's refs must be released without unregistering
    chains the survivor still attaches."""
    cfg, api, params = model
    rng = np.random.default_rng(41)
    shared = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
             for _ in range(2)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    kw = dict(n_slots=2, block_size=4, chunk_tokens=16,
              prefix_cache=True, **COMMON)
    eng = PagedDecodeEngine(api, params, **kw)
    eng.submit(prompts[0], 8)
    eng.step()
    eng.step()                     # request 0's prefix blocks registered
    eng.submit(prompts[1], 8)      # attaches the shared-prefix chain
    eng.step()
    assert eng.kv.prefix_hits > 0
    assert eng.cancel(0)
    fin = eng.run_until_drained()
    got = {r.request_id: r.generated for r in fin if not r.cancelled}
    assert set(got) == {1} and eng.cancelled == 1
    solo = PagedDecodeEngine(api, params, **kw)
    solo.submit(prompts[1], 8)
    ref = solo.run_until_drained()[0].generated
    assert got[1] == ref


def test_cancel_mid_spec_verify_token_identical(model):
    """Cancel a speculating request between verify steps: its draft/KV
    state is torn down whole (no dangling rewind), and the surviving
    speculating lanes still match the dense oracle exactly."""
    cfg, api, params = model
    prompts = _prompts(cfg, 3, lo=6, hi=12, seed=43)
    se = SlotDecodeEngine(api, params, n_slots=3, **COMMON)
    eng = PagedDecodeEngine(api, params, n_slots=3, block_size=4,
                            chunk_tokens=8, prefix_cache=True,
                            spec=True, draft_k=4, **COMMON)
    for p in prompts:
        eng.submit(p, 10)
        se.submit(p, 10)
    # step until the victim has emitted (so drafts have been verified
    # on its lane), then cancel it mid-flight
    for _ in range(40):
        eng.step()
        victim = next((r for r in eng.scheduler.running
                       if r.request_id == 0), None)
        if victim is not None and victim.generated:
            break
    assert eng.cancel(0)
    fin = eng.run_until_drained()
    got = {r.request_id: r.generated for r in fin if not r.cancelled}
    ref = {r.request_id: r.generated for r in se.run_until_drained()}
    assert eng.stats()["spec_verifications"] > 0
    assert set(got) == {1, 2}
    assert all(got[k] == ref[k] for k in got)


def test_cancel_while_swapped_out_purges_host_tier(model):
    """Cancel a preempted request whose blocks were swapped to the host
    tier: the cancel must purge its host payloads (they are reachable by
    no surviving chain) and survivors still match the oracle."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=8, hi=14, seed=47)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=8,
              prefix_cache=True, **COMMON)
    need = max(-(-(len(p) + 8) // 4) for p in prompts)
    pool = max(need + 1, (3 * need) // 2)
    eng = PagedDecodeEngine(api, params, num_blocks=pool,
                            host_swap=True, **kw)
    for p in prompts:
        eng.submit(p, 8)
    victim = None
    for _ in range(200):
        eng.step()
        victim = next((r for r in eng.scheduler.waiting
                       if r.n_preemptions > 0), None)
        if victim is not None and eng.scheduler.total_swap_outs > 0:
            break
        victim = None
        if not eng.has_work():
            break
    assert victim is not None, "pool never forced a swap-out preemption"
    vid = victim.request_id
    before = len(eng._host_tier)
    assert eng.cancel(vid)
    assert eng.host_purged > 0 or len(eng._host_tier) <= before
    fin = eng.run_until_drained()
    got = {r.request_id: r.generated for r in fin if not r.cancelled}
    free_run = PagedDecodeEngine(api, params, **kw)
    for p in prompts:
        free_run.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    assert set(got) == set(ref) - {vid}
    assert all(got[k] == ref[k] for k in got)


def test_cancel_everything_drains_pool_and_host_tier(model):
    """The leak wall: after cancelling EVERY in-flight request, the block
    pool returns to fully free (only the null block reserved), the prefix
    cache holds nothing, the host swap tier is empty, and no queued
    swap-ins survive — cancellation reclaims all three tiers."""
    cfg, api, params = model
    rng = np.random.default_rng(53)
    shared = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([
        shared, rng.integers(0, cfg.vocab_size, 6).astype(np.int32)])
        for _ in range(5)]
    need = max(-(-(len(p) + 32) // 4) for p in prompts)
    eng = PagedDecodeEngine(api, params, n_slots=3, block_size=4,
                            chunk_tokens=8, prefix_cache=True,
                            host_swap=True, num_blocks=need + 3,
                            **COMMON)
    for p in prompts:                  # max_new large: nothing finishes
        eng.submit(p, 32)
    for _ in range(6):                 # mid-flight, preempting, swapping
        eng.step()
    for rid in range(len(prompts)):
        eng.cancel(rid)
    assert not eng.has_work()
    assert eng.kv.allocator.num_allocated == 0
    assert eng.kv.num_free_blocks == eng.num_blocks - 1
    assert not eng.kv._cached and not eng.kv._lru
    assert len(eng._host_tier) == 0
    assert not eng.kv.take_swap_ins()
    assert eng.cancelled == len(prompts)
    assert len(eng.run_until_drained()) == len(prompts)
    assert eng.stats()["released_seqs"] > 0


# ---------------------------------------------------------------------------
# tiered KV: int8 block storage + device->host swap tier, pinned corners
# ---------------------------------------------------------------------------
def test_int8_engine_token_identical_to_fp_engine(model):
    """The int8 acceptance gate: greedy outputs with int8 KV blocks (+
    per-block scales dequantized inside the attention references/kernels)
    exactly match the fp32-cache engine on this workload."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=4, hi=14, seed=5)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=6,
              cache_len=64, compute_dtype=jnp.float32)
    fp = PagedDecodeEngine(api, params, cache_dtype=jnp.float32, **kw)
    q8 = PagedDecodeEngine(api, params, cache_dtype=jnp.int8, **kw)
    for p in prompts:
        fp.submit(p, 8)
        q8.submit(p, 8)
    done_f = {r.request_id: r.generated for r in fp.run_until_drained()}
    done_q = {r.request_id: r.generated for r in q8.run_until_drained()}
    assert done_q == done_f and len(done_q) == len(prompts)


def test_int8_swap_roundtrip_bit_identical(model):
    """Swap-out -> host tier -> swap-in must reproduce the device block
    byte-for-byte: int8 planes AND their float32 scale planes survive the
    round trip exactly (no requantization, no dtype laundering)."""
    cfg, api, params = model
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = PagedDecodeEngine(api, params, n_slots=1, block_size=4,
                            chunk_tokens=8, prefix_cache=True,
                            host_swap=True, cache_len=64,
                            cache_dtype=jnp.int8,
                            compute_dtype=jnp.float32)
    eng.submit(prompt, 4)
    ref = eng.run_until_drained()[0].generated
    assert eng.kv._cached                  # finished chain sits on the LRU
    snap = {d: eng._read_block_payload(b)
            for d, b in eng.kv._cached.items()}
    while eng.kv._cached:                  # evict everything -> swap out
        assert eng.kv._evict_one()
    for d, p0 in snap.items():
        ent = eng._host_tier[d]["payload"]
        for part in p0:
            for name in p0[part]:
                assert p0[part][name].dtype == ent[part][name].dtype
                assert np.array_equal(p0[part][name], ent[part][name])
    # resubmit: the prefix returns from the host tier, not from recompute
    eng.submit(prompt, 4)
    got = eng.run_until_drained()[0].generated
    assert got == ref
    assert eng.stats()["swap_ins"] > 0
    for d, p0 in snap.items():
        blk = eng.kv.digest_block(d)
        if blk is None:
            continue
        p1 = eng._read_block_payload(blk)
        for part in p0:
            for name in p0[part]:
                assert np.array_equal(p0[part][name], p1[part][name])


def test_swap_oversubscribed_pool_token_identical(model):
    """Pool at ~half the workload's total block demand: the swap tier
    restores evicted/preempted blocks from the host instead of
    recomputing, and both the swap and recompute engines still match the
    free-running engine token-for-token."""
    cfg, api, params = model
    prompts = _prompts(cfg, 8, lo=8, hi=16, seed=37)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=8, prefix_cache=True,
              **COMMON)
    # pool well under the CONCURRENT working set (n_slots full seqs), so
    # admissions preempt and preempted chains must come back from the host
    need = max(-(-(len(p) + 8) // 4) for p in prompts)
    pool = max(need + 1, (3 * need) // 2)
    swap = PagedDecodeEngine(api, params, num_blocks=pool,
                             host_swap=True, **kw)
    reco = PagedDecodeEngine(api, params, num_blocks=pool,
                             host_swap=False, **kw)
    free_run = PagedDecodeEngine(api, params, **kw)
    for p in prompts:
        swap.submit(p, 8)
        reco.submit(p, 8)
        free_run.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    got_s = {r.request_id: r.generated for r in swap.run_until_drained()}
    got_r = {r.request_id: r.generated for r in reco.run_until_drained()}
    assert got_s == ref and got_r == ref
    s = swap.stats()
    assert s["preemptions"] > 0            # the pool really was too small
    assert s["swap_outs"] > 0 and s["swap_ins"] > 0
    assert reco.stats()["swap_ins"] == 0


def test_swap_thrash_during_cow_token_identical(model):
    """A full-match re-admission whose chain HEAD sits on the host tier
    while its tail block is still device-cached: the admission queues
    swap-ins for the head blocks AND CoW-forks the shared tail block in
    the same step, so the engine must land swap-in payloads before it
    applies the copy ops — outputs must stay exact."""
    cfg, api, params = model
    rng = np.random.default_rng(33)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    kw = dict(n_slots=2, block_size=4, chunk_tokens=6, **COMMON)
    eng = PagedDecodeEngine(api, params, prefix_cache=True,
                            host_swap=True, **kw)
    eng.submit(prompt, 6)
    first = eng.run_until_drained()[0].generated
    # prompt + the first generated token = exactly three cached full
    # blocks, so the resubmission below is a FULL match of the chain
    p2 = np.concatenate([prompt, np.asarray(first[:1], np.int32)])
    assert len(p2) % 4 == 0
    for _ in range(2):            # push the chain head to the host tier
        assert eng.kv._evict_one()
    pre_cow = eng.kv.cow_copies
    eng.submit(p2, 6)
    got = eng.run_until_drained()[0].generated
    assert eng.stats()["swap_ins"] >= 2   # the head came from the host
    assert eng.kv.cow_copies > pre_cow    # the tail block was CoW-forked
    # oracle: the same two requests through a cache-less engine
    free_run = PagedDecodeEngine(api, params, prefix_cache=False, **kw)
    free_run.submit(prompt, 6)
    ref1 = free_run.run_until_drained()[0].generated
    free_run.submit(p2, 6)
    ref2 = free_run.run_until_drained()[0].generated
    assert first == ref1 and got == ref2


def test_swap_with_spec_rewind_token_identical(model):
    """Speculative decode (drafts + KV rewind) over a thrashing pool with
    the swap tier on: rewinds only ever drop draft tails, never a
    swapped-in committed block, and the oracle outputs survive."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=6, hi=14, seed=35)
    se = SlotDecodeEngine(api, params, n_slots=3, **COMMON)
    for p in prompts:
        se.submit(p, 10)
    ref = {r.request_id: r.generated for r in se.run_until_drained()}
    # scripted drafts with exactly one correct token per window guarantee
    # a rewind on every verification (the n-gram proposer all-accepts on
    # the smoke model, which would leave the rewind path untested here)
    targets = [list(map(int, p)) + ref[i] for i, p in enumerate(prompts)]
    tight = PagedDecodeEngine(
        api, params, n_slots=3, block_size=4, chunk_tokens=6,
        prefix_cache=True, spec=True, draft_k=4, num_blocks=10,
        host_swap=True,
        proposer=_ScriptedProposer(targets, wrong_from=1,
                                   vocab=cfg.vocab_size),
        **COMMON)
    for p in prompts:
        tight.submit(p, 10)
    got = {r.request_id: r.generated for r in tight.run_until_drained()}
    assert got == ref
    s = tight.stats()
    assert s["swap_ins"] > 0 and s["kv_rewinds"] > 0


def test_swap_preemption_prefers_swap_over_recompute(model):
    """When the pool forces a preemption, a victim whose blocks are
    registered in the prefix cache is counted as swapped out (its blocks
    survive on the host) rather than thrown away for recompute."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=6, hi=14, seed=9)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=6, prefix_cache=True,
              **COMMON)
    tight = PagedDecodeEngine(api, params, num_blocks=10, host_swap=True,
                              **kw)
    free_run = PagedDecodeEngine(api, params, **kw)
    for p in prompts:
        tight.submit(p, 8)
        free_run.submit(p, 8)
    ref = {r.request_id: r.generated for r in free_run.run_until_drained()}
    got = {r.request_id: r.generated for r in tight.run_until_drained()}
    assert got == ref
    s = tight.stats()
    assert s["preemptions"] > 0
    assert s["preempt_swap_outs"] > 0


# ---------------------------------------------------------------------------
# speculative decode: accept-rule corners, pinned without hypothesis
# ---------------------------------------------------------------------------
class _ScriptedProposer(Proposer):
    """Test proposer with a known accept outcome: drafts the TRUE greedy
    continuation (from a baseline run), corrupting every draft from depth
    ``wrong_from`` on — so exactly ``wrong_from`` drafts are accepted per
    verification (all of them when ``wrong_from`` is None)."""

    def __init__(self, targets, wrong_from=None, vocab=2):
        self.targets = [list(map(int, t)) for t in targets]
        self.wrong_from = wrong_from
        self.vocab = vocab

    def propose(self, tokens, k):
        toks = [int(t) for t in tokens]
        for t in self.targets:
            if len(t) > len(toks) and t[:len(toks)] == toks:
                out = t[len(toks):len(toks) + k]
                if self.wrong_from is not None:
                    out = [x if i < self.wrong_from
                           else (x + 1) % self.vocab
                           for i, x in enumerate(out)]
                return out
        return []


def _run_spec_slice(model, wrong_from, *, draft_k=4, max_new=10,
                    prompt_len=6, block_size=4, n_requests=3):
    """Drive the spec engine with a scripted proposer against the
    dense-slot oracle; returns the engine for slice-specific stats
    asserts.  Geometry: prompt_len=6 with block_size=4 puts the first
    verification window (positions 6..10) astride the block boundary at
    8, so partial accepts rewind across it."""
    cfg, api, params = model
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_requests)]
    base = SlotDecodeEngine(api, params, n_slots=n_requests, **COMMON)
    for p in prompts:
        base.submit(p, max_new)
    ref = {r.request_id: r.generated for r in base.run_until_drained()}
    targets = [list(map(int, p)) + ref[i] for i, p in enumerate(prompts)]
    eng = PagedDecodeEngine(
        api, params, n_slots=n_requests, block_size=block_size,
        prefix_cache=False, spec=True, draft_k=draft_k,
        proposer=_ScriptedProposer(targets, wrong_from=wrong_from,
                                   vocab=cfg.vocab_size),
        **COMMON)
    for p in prompts:
        eng.submit(p, max_new)
    got = {r.request_id: r.generated for r in eng.run_until_drained()}
    assert got == ref                       # token identity, always
    # drained pool: every block back, none orphaned or double-freed
    assert eng.kv.num_free_blocks == eng.num_blocks - 1
    assert eng.kv.allocator.num_allocated == 0
    return eng


def test_spec_all_accept_token_identical(model):
    """Every draft matches the model's argmax: verification accepts whole
    windows, no rewinds, several tokens per decode emission — outputs
    still exactly match the oracle."""
    eng = _run_spec_slice(model, wrong_from=None)
    s = eng.stats()
    assert s["tokens_drafted"] > 0
    assert s["draft_tokens_accepted"] == s["tokens_drafted"]
    assert s["accepted_per_spec_step"] > 1.5
    assert s["kv_rewinds"] == 0             # nothing to roll back
    assert eng.steps < 3 * eng.n_slots + eng.stats()["tokens_decoded"]


def test_spec_all_reject_token_identical(model):
    """Every draft is wrong: each verification degrades to exactly the
    plain one-token decode (bonus token only), every draft slot is
    rewound, and blocks that only held rejected drafts return to the
    pool."""
    eng = _run_spec_slice(model, wrong_from=0)
    s = eng.stats()
    assert s["tokens_drafted"] > 0
    assert s["draft_tokens_accepted"] == 0
    assert s["accepted_per_spec_step"] == 1.0
    assert s["kv_rewinds"] == s["spec_verifications"]
    assert s["kv_tokens_rewound"] == s["tokens_drafted"]
    assert eng.kv.blocks_rewound > 0        # draft-only blocks were freed


def test_spec_partial_accept_straddles_block_boundary(model):
    """One draft accepted per window: the accept watermark (8 tokens on
    the first verification) lands exactly on the 4-token block boundary
    while the rejected drafts spill into the next block — the rewind
    frees that block without touching the accepted one."""
    eng = _run_spec_slice(model, wrong_from=1)
    s = eng.stats()
    assert 0 < s["draft_tokens_accepted"] < s["tokens_drafted"]
    assert s["kv_rewinds"] > 0
    assert eng.kv.blocks_rewound > 0
    # every emission = 1 accepted draft + the bonus token
    assert s["accepted_per_spec_step"] == 2.0


def test_spec_engine_token_identical_to_nonspec_engine(model):
    """The spec=False baseline pins today's one-token-per-step decode;
    the speculative engine (default n-gram proposer) must reproduce its
    outputs exactly while taking no more engine steps."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, lo=3, hi=10, seed=23)
    kw = dict(n_slots=3, block_size=4, chunk_tokens=8, **COMMON)
    sp = PagedDecodeEngine(api, params, spec=True, draft_k=4, **kw)
    ns = PagedDecodeEngine(api, params, spec=False, **kw)
    assert sp.spec and not ns.spec
    for p in prompts:
        sp.submit(p, 16)
        ns.submit(p, 16)
    done_s = {r.request_id: r.generated for r in sp.run_until_drained()}
    done_n = {r.request_id: r.generated for r in ns.run_until_drained()}
    assert done_s == done_n and len(done_s) == len(prompts)
    assert sp.steps <= ns.steps
    # the smoke model's greedy tails repeat, so n-gram lookup must land
    assert sp.stats()["draft_tokens_accepted"] > 0


def _check_scheduler_flat_invariants(seed, n_lanes, token_budget,
                                     chunk_tokens, num_blocks):
    from repro.serving import KVCacheManager, Request, Scheduler, \
        SchedulerConfig
    bs = 2
    rng = np.random.default_rng(seed)
    kv = KVCacheManager(num_blocks, bs, max_blocks_per_seq=8)
    sched = Scheduler(SchedulerConfig(n_lanes=n_lanes,
                                      token_budget=token_budget,
                                      chunk_tokens=chunk_tokens,
                                      fill_to_bucket=True), kv)
    budget = sched._budget()
    rid = 0
    for _ in range(30):
        if rng.random() < 0.5 and rid < 8:
            plen = int(rng.integers(1, 13))
            if -(-(plen + 2) // bs) <= 8:      # serveable under the ceiling
                sched.add(Request(rid, rng.integers(
                    0, 100, plen).astype(np.int32), 2))
                rid += 1
        if not sched.has_work():
            continue
        try:
            d = sched.schedule()
        except RuntimeError:
            break                              # pool too small for 1 seq
        total = sum(d.num_scheduled.values())
        assert total <= budget                 # budget invariant
        batch = RaggedBatch.build(d, kv, n_lanes, bs, cap=budget)
        assert batch.total_tokens == total
        assert batch.padded_tokens >= max(total, 1)
        # segment-tile view: cu_seqlens partition the real stream, every
        # scheduled token is covered by exactly one tile, and each tile's
        # lane/position metadata agrees with the per-token arrays
        from repro.serving.batch import (TILE_HI, TILE_LANE, TILE_LO,
                                         TILE_POS0)
        tm = batch.tiles(n_lanes, tile=4)
        assert tm.cu_seqlens[0] == 0 and tm.cu_seqlens[-1] == total
        real = tm.meta[:, :tm.n_tiles]
        assert (real[TILE_HI] - real[TILE_LO]).sum() == total
        for t in range(tm.n_tiles):
            lo, hi = real[TILE_LO, t], real[TILE_HI, t]
            assert lo < hi and np.all(tm.row_tile[lo:hi] == t)
            assert np.all(batch.token_lane[lo:hi] == real[TILE_LANE, t])
            assert np.all(batch.token_pos[lo:hi]
                          == real[TILE_POS0, t] + np.arange(hi - lo))
        covered = set()
        for r in d.scheduled:
            n = d.num_scheduled[r.request_id]
            assert n >= 1
            assert r.cursor + n <= len(r.feed)     # never past the feed
            assert kv.n_tokens(r.request_id) == r.cursor + n
            off = batch.q_starts[r.request_id]
            seg = range(off, off + n)
            assert not covered & set(seg)          # disjoint segments
            covered |= set(seg)
            table = kv.block_table(r.request_id)
            for i, t in enumerate(seg):
                p = r.cursor + i
                assert batch.token_pos[t] == p
                assert batch.token_lane[t] == r.lane
                assert batch.slot_mapping[t] == \
                    table[p // bs] * bs + p % bs
        assert len(covered) == total
        # the engine's role: consume the scheduled tokens
        for r in list(d.scheduled):
            n = d.num_scheduled[r.request_id]
            if r.cursor + n == len(r.feed):
                r.generated.append(int(rng.integers(0, 100)))
                r.feed.append(r.generated[-1])
            r.cursor += n
            if len(r.generated) >= r.max_new_tokens:
                sched.finish(r)


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n_lanes=st.integers(1, 4),
    token_budget=st.sampled_from([0, 3, 7, 16]),
    chunk_tokens=st.sampled_from([1, 2, 5, 16]),
    num_blocks=st.integers(4, 24),
)
def test_fuzz_scheduler_flat_batch_invariants(seed, n_lanes, token_budget,
                                              chunk_tokens, num_blocks):
    """Host-only fuzz (no model): every schedule() under random load keeps
    the flat-batch invariants — budget respected, no lane past its feed,
    KV slots granted for exactly the scheduled tokens, and the RaggedBatch
    segments contiguous, disjoint, and consistent with the block tables."""
    _check_scheduler_flat_invariants(seed, n_lanes, token_budget,
                                     chunk_tokens, num_blocks)


@pytest.mark.parametrize("seed", range(6))
def test_scheduler_flat_batch_invariants_pinned(seed):
    """No-hypothesis slice of the scheduler fuzz (CI runs the full sweep)."""
    _check_scheduler_flat_invariants(seed, n_lanes=1 + seed % 4,
                                     token_budget=(0, 3, 7, 16)[seed % 4],
                                     chunk_tokens=(1, 2, 5, 16)[seed % 4],
                                     num_blocks=5 + 3 * seed)
