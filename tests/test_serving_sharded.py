"""Mesh-sharded serving: the token-identity wall across devices.

Four layers, all on a CPU mesh of >= 4 virtual devices (conftest forces
``--xla_force_host_platform_device_count=4``):

  * **Tensor parallelism** — a single engine whose KV pool is sharded
    over ``kv_heads`` on the mesh's "model" axis must emit exactly the
    single-device tokens, while actually communicating (collectives in
    the compiled step).  When kv heads don't divide the axis (GQA), the
    pool replicates cleanly instead of crashing — same degradation rule
    as the training-side param specs.
  * **Data parallelism** — the :class:`ShardedDecodeEngine` front routes
    requests to the least-loaded slice (by outstanding tokens) across
    full per-slice engines; for dense models the fleet output equals the
    single-device output request-for-request, and a long-running
    occupant never starves later short requests (the round-robin
    regression pinned below).
  * **MoE caveat, pinned as an invariant** — expert-choice capacity makes
    MoE logits depend on batch composition, so a DP fleet is NOT
    token-identical to one whole-fleet engine.  The invariant that DOES
    hold (and is asserted): the sharded front equals plain single-device
    engines fed the same per-slice request subsets — slicing, not
    sharding, is the semantic change.
  * **Transfer** — KV blocks exported from a tensor-parallel engine
    import bit-identically into a single-device engine (and back), and
    the importer prefix-hits like it prefilled locally: the wire format
    is sharding-agnostic because payloads are gathered to host.
"""
import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import (DecodeEngine, KVShipment, PagedDecodeEngine,
                               ShardedDecodeEngine)
    from repro.launch.mesh import make_host_mesh
    HAVE_JAX = True
except ImportError:                                    # pragma: no cover
    HAVE_JAX = False

pytestmark = [
    pytest.mark.skipif(not HAVE_JAX, reason="jax not available"),
    pytest.mark.skipif(
        HAVE_JAX and len(jax.devices()) < 4,
        reason="needs >=4 devices (conftest forces 4 virtual CPU devices; "
               "set XLA_FLAGS=--xla_force_host_platform_device_count=4)"),
]

COMMON = dict(cache_len=64, cache_dtype=jnp.float32,
              compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-7b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.fixture(scope="module")
def moe_model():
    cfg = get_config("qwen3-moe-235b-a22b").smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _tp_mesh(tp):
    """Single-slice mesh: 1 data slice x tp-way tensor parallel."""
    devs = np.array(jax.devices()[:tp]).reshape(1, tp)
    return Mesh(devs, ("data", "model"))


def _drain(eng, prompts, max_new=6, arrival_every=1):
    """Submit with optional staggering, run to empty, return {id: tokens}."""
    pending = list(prompts)
    step = 0
    while pending or eng.has_work():
        if pending and step % arrival_every == 0:
            eng.submit(pending.pop(0), max_new)
        eng.step()
        step += 1
        assert step < 2000, "engine did not drain"
    return {r.request_id: r.generated for r in eng.run_until_drained()}


def _prompts(cfg, n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# tensor parallelism: one engine, sharded KV pool
# ---------------------------------------------------------------------------
def test_tp_engine_token_identical_and_actually_sharded(model):
    """tp=2 engine == single-device engine token-for-token, with the KV
    pool genuinely cut over kv_heads and collectives in the step."""
    cfg, api, params = model
    prompts = _prompts(cfg, 4, seed=1)
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    tp = PagedDecodeEngine(api, params, n_slots=2, mesh=_tp_mesh(2),
                           **COMMON)
    assert tp.tp == 2 and tp.kv_heads_sharded    # gemma smoke: 4 kv heads
    got_ref = _drain(ref, prompts)
    got_tp = _drain(tp, prompts)
    assert got_tp == got_ref
    s = tp.stats()
    assert s["collectives_per_step"] > 0         # TP really communicates
    assert s["collective_ops"] >= s["collectives_per_step"]
    # regression pin for the frontend concat placement: committing the
    # token/position feed to a replicated layout BEFORE the concat keeps
    # XLA from re-replicating the batch mid-step — the dense smoke model
    # compiles to exactly 3 collectives per decode step (one per fused
    # attention/MLP reduce), and any placement slip shows up as extra
    # all-gathers here
    assert s["collectives_per_step"] <= 3


def test_tp4_token_identical_over_all_devices(model):
    """Full-width tensor parallelism (tp = all 4 devices) through the
    DecodeEngine factory stays a single (non-fleet) engine and matches."""
    cfg, api, params = model
    prompts = _prompts(cfg, 3, seed=2)
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    tp = DecodeEngine(api, params, paged=True, n_slots=2,
                      mesh=make_host_mesh(model_parallel=4), **COMMON)
    assert isinstance(tp, PagedDecodeEngine) and tp.tp == 4
    assert _drain(tp, prompts) == _drain(ref, prompts)


def test_gqa_nondividing_kv_replicates_token_identical(moe_model):
    """qwen3-moe smoke has a single kv head: 1 % 2 != 0, so the pool must
    degrade to replication (kv_heads_sharded == 0) — and still produce
    the single-device tokens with the MLP/MoE shards live."""
    cfg, api, params = moe_model
    prompts = _prompts(cfg, 3, seed=3)
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    tp = PagedDecodeEngine(api, params, n_slots=2, mesh=_tp_mesh(2),
                           **COMMON)
    assert tp.tp == 2 and not tp.kv_heads_sharded
    assert _drain(tp, prompts) == _drain(ref, prompts)
    assert tp.stats()["collectives_per_step"] > 0


# ---------------------------------------------------------------------------
# data parallelism: the sharded front
# ---------------------------------------------------------------------------
def test_dp_front_token_identical_to_single_engine(model):
    """Dense model, 4 slices, staggered arrivals: the fleet's outputs
    match the single-device engine request-for-request (greedy decode is
    schedule-independent, so routing can't change tokens)."""
    cfg, api, params = model
    prompts = _prompts(cfg, 6, seed=4)
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    dp = DecodeEngine(api, params, paged=True, n_slots=2,
                      mesh=make_host_mesh(), **COMMON)
    assert isinstance(dp, ShardedDecodeEngine) and dp.n_slices == 4
    assert _drain(dp, prompts, arrival_every=2) == \
        _drain(ref, prompts, arrival_every=2)


def test_dp_tp_front_token_identical(model):
    """2 slices x 2-way TP (the full mesh shape) against the oracle."""
    cfg, api, params = model
    prompts = _prompts(cfg, 5, seed=5)
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    dptp = ShardedDecodeEngine(api, params,
                               mesh=make_host_mesh(model_parallel=2),
                               n_slots=2, **COMMON)
    assert dptp.n_slices == 2 and dptp.engines[0].tp == 2
    assert _drain(dptp, prompts) == _drain(ref, prompts)


def test_moe_dp_front_token_identity_per_slice(moe_model):
    """MoE + DP: capacity dropping makes logits depend on which requests
    share a batch, so the fleet need not match one whole-fleet engine.
    The sharded front must instead equal plain single-device engines fed
    the same per-slice subsets — proving the mesh machinery adds nothing
    beyond the (inherent, documented) batch-composition effect.  The
    groups come from the front's own routing table (``_route``), so the
    invariant holds under any routing policy."""
    cfg, api, params = moe_model
    prompts = _prompts(cfg, 6, seed=6)
    dp = ShardedDecodeEngine(api, params, mesh=make_host_mesh(),
                             n_slots=2, **COMMON)
    gids = [dp.submit(p, 6) for p in prompts]
    got = {r.request_id: r.generated for r in dp.run_until_drained()}
    groups: dict = {}
    for gid in gids:                # gid order == per-slice local order
        groups.setdefault(dp._route[gid][0], []).append(gid)
    for i, members in groups.items():
        solo = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
        lids = [solo.submit(prompts[g], 6) for g in members]
        mine = {r.request_id: r.generated
                for r in solo.run_until_drained()}
        for lid, gid in zip(lids, members):
            assert got[gid] == mine[lid], (
                f"slice {i} diverged from its single-device twin")


def test_least_loaded_routing_avoids_starvation_token_identical(model):
    """Regression: round-robin would park one of the short requests
    (gid % n_slices == 0) behind the long-running occupant of slice 0
    while other slices idle; least-loaded routing must send every short
    to an idle slice — and the dense fleet still matches the
    single-device oracle token-for-token."""
    cfg, api, params = model
    rng = np.random.default_rng(10)
    long_p = rng.integers(0, cfg.vocab_size, 30).astype(np.int32)
    shorts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
              for _ in range(4)]
    dp = DecodeEngine(api, params, paged=True, n_slots=2,
                      mesh=make_host_mesh(), **COMMON)
    assert isinstance(dp, ShardedDecodeEngine) and dp.n_slices == 4
    g_long = dp.submit(long_p, 24)
    assert dp._route[g_long][0] == 0      # empty fleet: lowest index
    g_shorts = [dp.submit(p, 4) for p in shorts]
    # round-robin would route g_shorts[3] (gid 4 -> 4 % 4 == 0) to the
    # busy slice; least-loaded must keep every short off slice 0
    assert all(dp._route[g][0] != 0 for g in g_shorts)
    assert {dp._route[g][0] for g in g_shorts} == {1, 2, 3}
    got = {r.request_id: r.generated for r in dp.run_until_drained()}
    ref = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    ref.submit(long_p, 24)
    for p in shorts:
        ref.submit(p, 4)
    want = {r.request_id: r.generated for r in ref.run_until_drained()}
    assert got == want


# ---------------------------------------------------------------------------
# transfer across sharding boundaries
# ---------------------------------------------------------------------------
def test_sharded_export_import_roundtrip_token_identical(model):
    """KV prefill exported from a tp=2 engine imports bit-identically
    into a single-device engine (and the reverse), and the importer
    serves the warm prompt with a prefix hit and unchanged tokens."""
    cfg, api, params = model
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)

    src = PagedDecodeEngine(api, params, n_slots=2, mesh=_tp_mesh(2),
                            **COMMON)
    src.submit(prompt, 1)
    src.run_until_drained()
    ship = src.export_kv_prefix(prompt)
    assert ship.n_blocks == 37 // src.block_size
    back = KVShipment.deserialize(ship.serialize())

    # sharded -> single-device: bit identity in the importer's pool
    dst = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    stats = dst.import_kv_shipment(back)
    assert stats["imported"] == ship.n_blocks
    for rec in ship.blocks:
        blk = dst.kv._cached[rec.digest]
        got = dst._read_block_payload(blk)
        for part in rec.payload:
            for kv in ("k", "v"):
                np.testing.assert_array_equal(got[part][kv],
                                              rec.payload[part][kv])
    # the warmed importer prefix-hits and emits the cold engine's tokens
    cold = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    assert _drain(dst, [prompt]) == _drain(cold, [prompt])
    assert dst.kv.prefix_hits > 0

    # single-device -> sharded: the mirror direction also lands clean
    plain = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    plain.submit(prompt, 1)
    plain.run_until_drained()
    ship2 = plain.export_kv_prefix(prompt)
    dst2 = PagedDecodeEngine(api, params, n_slots=2, mesh=_tp_mesh(2),
                             **COMMON)
    s2 = dst2.import_kv_shipment(KVShipment.deserialize(ship2.serialize()))
    assert s2["imported"] == ship2.n_blocks
    cold2 = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    assert _drain(dst2, [prompt]) == _drain(cold2, [prompt])
    assert dst2.kv.prefix_hits > 0


def test_sharded_front_import_is_fleet_wide(model):
    """A shipment imported through the front lands on EVERY slice (each
    has its own pool), so any route serves the prefix warm; the digests
    every slice holds form the safe dedup set."""
    cfg, api, params = model
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    src = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    src.submit(prompt, 1)
    src.run_until_drained()
    ship = src.export_kv_prefix(prompt)

    dp = ShardedDecodeEngine(api, params, mesh=make_host_mesh(),
                             n_slots=2, **COMMON)
    stats = dp.import_kv_shipment(ship)
    assert stats["imported"] == ship.n_blocks * dp.n_slices
    assert dp.cached_digests() == {b.digest for b in ship.blocks}
    # every route decodes the warm prompt to the cold engine's tokens
    cold = PagedDecodeEngine(api, params, n_slots=2, **COMMON)
    want = _drain(cold, [prompt] * dp.n_slices)
    assert _drain(dp, [prompt] * dp.n_slices) == want
    assert all(e.kv.prefix_hits > 0 for e in dp.engines)


# ---------------------------------------------------------------------------
# stats contract
# ---------------------------------------------------------------------------
def test_sharded_stats_report_per_slice_and_collectives(model):
    """stats() exposes the per-slice/per-shard breakdown the bench and
    SLO work read imbalance from, and the lists sum to the aggregates."""
    cfg, api, params = model
    dp = ShardedDecodeEngine(api, params,
                             mesh=make_host_mesh(model_parallel=2),
                             n_slots=2, **COMMON)
    _drain(dp, _prompts(cfg, 4, seed=9))
    s = dp.stats()
    assert s["slices"] == 2 and s["tp"] == 2
    assert s["tokens_decoded"] == sum(s["tokens_decoded_per_slice"])
    assert s["tokens_prefilled"] == sum(s["tokens_prefilled_per_slice"])
    assert s["collective_ops"] == sum(s["collective_ops_per_slice"])
    assert all(t > 0 for t in s["tokens_decoded_per_slice"])
    assert len(s["per_slice"]) == 2
    assert all(p["tp"] == 2 for p in s["per_slice"])
    # single-engine mesh stats carry the same accounting keys
    tp = PagedDecodeEngine(api, params, n_slots=2, mesh=_tp_mesh(2),
                           **COMMON)
    for k in ("tp", "kv_heads_sharded", "collectives_per_step",
              "collective_ops"):
        assert k in tp.stats()
