"""Property tests for BlockAllocator / KVCacheManager invariants.

Random interleavings of begin_seq / append / fork / free must preserve:
refcounts never negative, every block accounted for (free + allocated =
pool), fork+free round-trips to an empty pool, and prefix-hash lookups
never return partially-filled blocks (matches are always whole-block
multiples).  Runs under the optional-hypothesis shim (tests/_hyp.py):
plain skips without hypothesis, the full sweep in CI.
"""
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.serving import KVCacheManager

BS = 4          # block size
POOL = 17       # 16 usable blocks
CEIL = 8        # max blocks per seq


def _check_invariants(m: KVCacheManager) -> None:
    alloc = m.allocator
    for blk, refs in alloc._refs.items():
        assert refs > 0, f"block {blk} has refcount {refs}"
    assert 0 not in alloc._refs and 0 not in alloc._free
    assert alloc.num_free + alloc.num_allocated == alloc.num_blocks - 1
    # evictable blocks are a subset of cache-registered blocks with
    # exactly the cache's own hold left
    for blk in m._lru:
        assert blk in m._block_digest
        assert alloc.refcount(blk) == 1
    # per-seq tables only reference live blocks, sized to n_tokens
    for sid, seq in m._seqs.items():
        assert len(seq.table) >= m.blocks_needed(seq.n_tokens), sid
        for blk in seq.table:
            assert alloc.refcount(blk) >= 1, (sid, blk)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                          st.integers(1, 12)), max_size=40))
def test_random_op_interleavings_preserve_invariants(ops):
    """A random machine over begin_seq/append/free/fork keeps the pool
    consistent; whenever it runs out of blocks that surfaces as the
    documented RuntimeError, never a corrupted state."""
    m = KVCacheManager(POOL, BS, max_blocks_per_seq=CEIL,
                       enable_prefix_cache=True)
    live = set()
    next_id = [0]
    for kind, which, arg in ops:
        try:
            if kind == 0:                       # admit a new sequence
                sid = next_id[0]
                next_id[0] += 1
                feed = [(t * 7 + which) % 13 for t in range(arg + 1)]
                n = m.begin_seq(sid, feed)
                assert n % BS == 0 or n == len(feed) - 1
                assert n <= len(feed) - 1
                live.add(sid)
            elif kind == 1 and live:            # append tokens
                sid = sorted(live)[which % len(live)]
                for t in range(arg):
                    if m._seqs[sid].n_tokens >= CEIL * BS:
                        break
                    m.append_token(sid, (t * 3 + which) % 13)
            elif kind == 2 and live:            # free
                sid = sorted(live)[which % len(live)]
                m.free(sid)
                live.discard(sid)
            elif kind == 3 and live:            # fork at aligned length only
                sid = sorted(live)[which % len(live)]
                if m.n_tokens(sid) % BS == 0:
                    dst = next_id[0]
                    next_id[0] += 1
                    m.fork(sid, dst)
                    live.add(dst)
        except RuntimeError:
            pass                                # pool exhausted: legal
        _check_invariants(m)
    for sid in list(live):
        m.free(sid)
    _check_invariants(m)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, CEIL), st.integers(1, 4))
def test_fork_free_roundtrips_to_empty_pool(n_blocks, n_forks):
    """Forking a sequence any number of times and freeing everything
    returns every block to the free list (no prefix cache: no cache
    holds)."""
    m = KVCacheManager(64, BS, max_blocks_per_seq=CEIL)
    free0 = m.num_free_blocks
    m.allocate(0, n_blocks * BS)
    for i in range(n_forks):
        m.fork(0, 1 + i)
    for sid in range(n_forks + 1):
        m.free(sid)
    assert m.num_free_blocks == free0
    assert m.allocator.num_allocated == 0


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 3 * BS), st.integers(0, 3 * BS))
def test_prefix_lookup_never_matches_partial_blocks(prompt_len, extra):
    """Only blocks completely filled by a finished sequence are ever
    returned by the prefix lookup — a partially-written tail can never
    leak into a new sequence."""
    m = KVCacheManager(POOL, BS, max_blocks_per_seq=CEIL,
                       enable_prefix_cache=True)
    feed = list(range(prompt_len + extra))
    m.begin_seq(0, feed)
    for t in feed[m.n_tokens(0):]:
        m.append_token(0, t)
    m.free(0)
    matched = m.lookup_prefix(feed)
    assert matched % BS == 0
    assert matched == (len(feed) // BS) * BS
    # a shorter probe must never match beyond its own full blocks
    probe = feed[:prompt_len]
    got = m.lookup_prefix(probe)
    assert got % BS == 0 and got <= (len(probe) // BS) * BS


def test_refcounts_never_negative_on_double_free():
    m = KVCacheManager(8, BS, max_blocks_per_seq=4)
    m.allocate(0, BS)
    m.free(0)
    with pytest.raises(KeyError):
        m.free(0)
    assert all(r > 0 for r in m.allocator._refs.values())


def test_property_suite_runs_in_ci():
    """CI installs hypothesis; this canary fails there if the property
    sweep silently degraded to skips (see ci.yml gate)."""
    import os
    if os.environ.get("CI") and not HAVE_HYPOTHESIS:
        pytest.fail("CI must run the hypothesis property sweep")
