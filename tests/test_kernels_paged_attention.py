"""Paged-attention Pallas kernel (interpret mode) vs the pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_chunk,
                                           paged_attention_ragged,
                                           paged_attention_ragged_tiled)
from repro.kernels.ref import (paged_attention_chunk_reference,
                               paged_attention_ragged_reference,
                               paged_attention_ragged_tiled_reference,
                               paged_attention_reference,
                               pool_gather_stats)
from repro.kernels import ops
from repro.serving.batch import TILE_HI, TILE_LO, build_tile_map


def _setup(key, B, Hkv, G, D, num_blocks, bs, max_blocks, ctx, dtype):
    ks = jax.random.split(key, 3)
    H = Hkv * G
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k_pool = jax.random.normal(ks[1], (num_blocks, bs, Hkv, D), dtype)
    v_pool = jax.random.normal(ks[2], (num_blocks, bs, Hkv, D), dtype)
    tables = np.zeros((B, max_blocks), np.int32)
    free = list(range(1, num_blocks))
    for b in range(B):
        for j in range(-(-int(ctx[b]) // bs)):
            tables[b, j] = free.pop(0)
    return q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(ctx)


@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("window", [0, 5])
def test_kernel_matches_reference(key, G, window):
    B, Hkv, D, bs, max_blocks = 3, 2, 64, 8, 4
    num_blocks = B * max_blocks + 1
    ctx = np.array([1, 9, 26], np.int32)     # partial / mid / near-full
    q, kp, vp, tables, ctxj = _setup(key, B, Hkv, G, D, num_blocks, bs,
                                     max_blocks, ctx, jnp.float32)
    ref = paged_attention_reference(q, kp, vp, tables, ctxj, window=window)
    qg = q.reshape(B, Hkv, G, D)
    out = paged_attention(qg, kp, vp, tables, ctxj, window=window,
                          interpret=True).reshape(B, H := Hkv * G, D)
    assert out.shape == (B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_ignores_null_block_contents(key):
    """Garbage in the reserved null block must not leak into any lane."""
    B, Hkv, G, D, bs, max_blocks = 2, 1, 2, 32, 4, 3
    num_blocks = 8
    ctx = np.array([4, 6], np.int32)
    q, kp, vp, tables, ctxj = _setup(key, B, Hkv, G, D, num_blocks, bs,
                                     max_blocks, ctx, jnp.float32)
    out1 = paged_attention(q.reshape(B, Hkv, G, D), kp, vp, tables, ctxj,
                           interpret=True)
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(-1e4)
    out2 = paged_attention(q.reshape(B, Hkv, G, D), kp2, vp2, tables, ctxj,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_ops_wrapper_dispatches_to_reference_on_cpu(key):
    """On the CPU backend the wrapper must use the XLA reference path and
    accept the model-native (B, 1, H, D) query layout."""
    B, Hkv, G, D, bs, max_blocks = 2, 2, 2, 16, 4, 2
    ctx = np.array([3, 7], np.int32)
    q, kp, vp, tables, ctxj = _setup(key, B, Hkv, G, D, 8, bs,
                                     max_blocks, ctx, jnp.float32)
    out = ops.paged_attention(q[:, None], kp, vp, tables, ctxj)
    assert out.shape == (B, 1, Hkv * G, D)
    ref = paged_attention_reference(q, kp, vp, tables, ctxj)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               atol=1e-6)


def test_reference_masks_positions_beyond_ctx(key):
    """Rewriting KV entries at/after ctx_len must not change the output."""
    B, Hkv, G, D, bs, max_blocks = 1, 1, 1, 16, 4, 2
    ctx = np.array([5], np.int32)
    q, kp, vp, tables, ctxj = _setup(key, B, Hkv, G, D, 8, bs,
                                     max_blocks, ctx, jnp.float32)
    out1 = paged_attention_reference(q, kp, vp, tables, ctxj)
    blk = int(np.asarray(tables)[0, 1])      # holds positions 4..7
    kp2 = kp.at[blk, 2:].set(99.0)           # positions 6,7 >= ctx
    vp2 = vp.at[blk, 2:].set(-99.0)
    out2 = paged_attention_reference(q, kp2, vp2, tables, ctxj)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# variable q_len (chunked prefill) generalization
# ---------------------------------------------------------------------------
def _chunk_setup(key, B, Hkv, G, D, bs, max_blocks, C, starts, lens, dtype):
    ks = jax.random.split(key, 3)
    H = Hkv * G
    num_blocks = B * max_blocks + 1
    q = jax.random.normal(ks[0], (B, C, H, D), dtype)
    k_pool = jax.random.normal(ks[1], (num_blocks, bs, Hkv, D), dtype)
    v_pool = jax.random.normal(ks[2], (num_blocks, bs, Hkv, D), dtype)
    tables = np.zeros((B, max_blocks), np.int32)
    free = list(range(1, num_blocks))
    ends = starts + lens
    for b in range(B):
        for j in range(-(-int(ends[b]) // bs)):
            tables[b, j] = free.pop(0)
    return q, k_pool, v_pool, jnp.asarray(tables)


@pytest.mark.parametrize("G", [1, 2])
@pytest.mark.parametrize("window", [0, 5])
def test_chunk_kernel_matches_chunk_reference(key, G, window):
    """Variable q_len per lane with causal masking inside the chunk: the
    Pallas kernel (interpret mode) must match the pure-jnp chunk oracle on
    every real (non-padded) query row."""
    B, Hkv, D, bs, max_blocks, C = 3, 2, 32, 4, 6, 5
    starts = np.array([0, 3, 9], np.int32)   # fresh / mid-block / deep lane
    lens = np.array([5, 4, 2], np.int32)     # full chunk / padded / padded
    q, kp, vp, tables = _chunk_setup(key, B, Hkv, G, D, bs, max_blocks, C,
                                     starts, lens, jnp.float32)
    ref = paged_attention_chunk_reference(q, kp, vp, tables,
                                          jnp.asarray(starts), window=window)
    H = Hkv * G
    q5 = jnp.transpose(q.reshape(B, C, Hkv, G, D), (0, 2, 1, 3, 4))
    out = paged_attention_chunk(q5, kp, vp, tables, jnp.asarray(starts),
                                jnp.asarray(starts + lens), window=window,
                                interpret=True)
    out = jnp.transpose(out, (0, 2, 1, 3, 4)).reshape(B, C, H, D)
    for b in range(B):
        np.testing.assert_allclose(np.asarray(out[b, :lens[b]]),
                                   np.asarray(ref[b, :lens[b]]),
                                   atol=2e-5, rtol=2e-5)


def test_chunk_kernel_single_token_equals_decode_kernel(key):
    """C = 1 chunks must reproduce the decode kernel exactly (same online
    softmax sweep, q_starts = ctx - 1)."""
    B, Hkv, G, D, bs, max_blocks = 3, 2, 2, 32, 8, 4
    num_blocks = B * max_blocks + 1
    ctx = np.array([1, 9, 26], np.int32)
    q, kp, vp, tables, ctxj = _setup(key, B, Hkv, G, D, num_blocks, bs,
                                     max_blocks, ctx, jnp.float32)
    qg = q.reshape(B, Hkv, G, D)
    dec = paged_attention(qg, kp, vp, tables, ctxj, interpret=True)
    chk = paged_attention_chunk(qg[:, :, None], kp, vp, tables, ctxj - 1,
                                ctxj, interpret=True)[:, :, 0]
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(chk))


def test_chunk_reference_is_causal_inside_chunk(key):
    """Query c must not see kv positions written for later chunk tokens:
    corrupting position start+c+1 changes row c+1 but never row c."""
    B, Hkv, G, D, bs, max_blocks, C = 1, 1, 1, 16, 4, 3, 4
    starts = np.array([2], np.int32)
    lens = np.array([4], np.int32)
    q, kp, vp, tables = _chunk_setup(key, B, Hkv, G, D, bs, max_blocks, C,
                                     starts, lens, jnp.float32)
    out1 = paged_attention_chunk_reference(q, kp, vp, tables,
                                           jnp.asarray(starts))
    p = int(starts[0]) + 2                   # the chunk's 3rd position
    blk = int(np.asarray(tables)[0, p // bs])
    kp2 = kp.at[blk, p % bs].set(37.0)
    vp2 = vp.at[blk, p % bs].set(-37.0)
    out2 = paged_attention_chunk_reference(q, kp2, vp2, tables,
                                           jnp.asarray(starts))
    np.testing.assert_array_equal(np.asarray(out1[0, :2]),
                                  np.asarray(out2[0, :2]))
    assert not np.allclose(np.asarray(out1[0, 2]), np.asarray(out2[0, 2]))


def test_ops_chunk_wrapper_dispatches_to_reference_on_cpu(key):
    B, Hkv, G, D, bs, max_blocks, C = 2, 2, 2, 16, 4, 4, 3
    starts = np.array([0, 5], np.int32)
    lens = np.array([3, 2], np.int32)
    q, kp, vp, tables = _chunk_setup(key, B, Hkv, G, D, bs, max_blocks, C,
                                     starts, lens, jnp.float32)
    out = ops.paged_attention_chunk(q, kp, vp, tables, jnp.asarray(starts),
                                    jnp.asarray(lens))
    ref = paged_attention_chunk_reference(q, kp, vp, tables,
                                          jnp.asarray(starts))
    assert out.shape == (B, C, Hkv * G, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# ragged flat-token-stream generalization
# ---------------------------------------------------------------------------
# Each lane contributes a contiguous segment of (start, n) query tokens to
# one flat stream; {all-decode, one-big-prefill+decodes, multi-prefill}
# exercises the mixes the unified serving step actually schedules, with
# segment starts straddling block boundaries (start % bs != 0, segments
# crossing into the next block).
SEGMENT_MIXES = {
    "all_decode": [(4, 1), (9, 1), (0, 1), (14, 1)],
    "one_prefill_plus_decodes": [(3, 1), (0, 9), (7, 1)],
    "multi_prefill": [(2, 6), (0, 5), (5, 7)],
}


def _ragged_setup(key, segments, Hkv, G, D, bs, max_blocks, dtype):
    """Build pools + disjoint per-lane tables + the flat token metadata."""
    ks = jax.random.split(key, 3)
    H = Hkv * G
    T = sum(n for _, n in segments)
    n_lanes = len(segments)
    num_blocks = n_lanes * max_blocks + 1
    q = jax.random.normal(ks[0], (T, H, D), dtype)
    k_pool = jax.random.normal(ks[1], (num_blocks, bs, Hkv, D), dtype)
    v_pool = jax.random.normal(ks[2], (num_blocks, bs, Hkv, D), dtype)
    tables = np.zeros((n_lanes, max_blocks), np.int32)
    free = list(range(1, num_blocks))
    token_tables = np.zeros((T, max_blocks), np.int32)
    token_pos = np.zeros((T,), np.int32)
    off = 0
    for lane, (start, n) in enumerate(segments):
        for j in range(-(-(start + n) // bs)):
            tables[lane, j] = free.pop(0)
        token_tables[off:off + n] = tables[lane]
        token_pos[off:off + n] = start + np.arange(n)
        off += n
    return q, k_pool, v_pool, tables, token_tables, token_pos


@pytest.mark.parametrize("mix", sorted(SEGMENT_MIXES))
@pytest.mark.parametrize("G", [1, 2, 4])
def test_ragged_reference_matches_per_lane_chunk_reference(key, mix, G):
    """The flat-stream oracle must agree with the naive per-lane chunk
    oracle on every segment: flattening is a layout change, not a math
    change."""
    segments = SEGMENT_MIXES[mix]
    Hkv, D, bs, max_blocks = 2, 16, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    flat = paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                            jnp.asarray(tpos))
    off = 0
    for lane, (start, n) in enumerate(segments):
        per_lane = paged_attention_chunk_reference(
            q[None, off:off + n], kp, vp,
            jnp.asarray(tables[lane:lane + 1]),
            jnp.asarray([start], jnp.int32))
        np.testing.assert_allclose(np.asarray(flat[off:off + n]),
                                   np.asarray(per_lane[0]),
                                   atol=2e-5, rtol=2e-5)
        off += n


@pytest.mark.parametrize("mix", sorted(SEGMENT_MIXES))
@pytest.mark.parametrize("G", [1, 2])
@pytest.mark.parametrize("window", [0, 5])
def test_ragged_kernel_matches_ragged_reference(key, mix, G, window):
    """Pallas flat-stream kernel (interpret mode) vs the pure-jnp oracle
    across q_len mixes, GQA ratios, and block-straddling positions."""
    segments = SEGMENT_MIXES[mix]
    Hkv, D, bs, max_blocks = 2, 32, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    T, H, D = q.shape
    ref = paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                           jnp.asarray(tpos), window=window)
    qg = q.reshape(T, Hkv, G, D)
    out = paged_attention_ragged(qg, kp, vp, jnp.asarray(ttab),
                                 jnp.asarray(tpos), window=window,
                                 interpret=True).reshape(T, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ragged_single_token_rows_equal_decode_kernel(key):
    """A flat stream of pure decodes must reproduce the rectangular decode
    kernel row for row (same online-softmax sweep per token)."""
    segments = SEGMENT_MIXES["all_decode"]
    Hkv, G, D, bs, max_blocks = 2, 2, 32, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    T, H, _ = q.shape
    qg = q.reshape(T, Hkv, G, D)
    flat = paged_attention_ragged(qg, kp, vp, jnp.asarray(ttab),
                                  jnp.asarray(tpos), interpret=True)
    # the same tokens as a (B = T)-lane decode batch at ctx = pos + 1
    dec = paged_attention(qg, kp, vp, jnp.asarray(ttab),
                          jnp.asarray(tpos) + 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(dec))


def test_ragged_padding_rows_are_inert(key):
    """Null-table / position-0 padding rows (the bucket tail) must not
    fault and must not change any real row's output."""
    segments = SEGMENT_MIXES["multi_prefill"]
    Hkv, G, D, bs, max_blocks = 2, 2, 16, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    T = q.shape[0]
    pad = 6
    qp = jnp.concatenate([q, jnp.zeros((pad,) + q.shape[1:], q.dtype)])
    ttab_p = np.concatenate([ttab, np.zeros((pad, max_blocks), np.int32)])
    tpos_p = np.concatenate([tpos, np.zeros((pad,), np.int32)])
    ref = paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                           jnp.asarray(tpos))
    out = paged_attention_ragged_reference(qp, kp, vp, jnp.asarray(ttab_p),
                                           jnp.asarray(tpos_p))
    np.testing.assert_array_equal(np.asarray(out[:T]), np.asarray(ref))
    assert np.all(np.isfinite(np.asarray(out)))      # garbage, but finite


def test_ragged_kernel_ignores_null_block_contents(key):
    """Scribbling the reserved null block must not leak into any lane."""
    segments = SEGMENT_MIXES["one_prefill_plus_decodes"]
    Hkv, G, D, bs, max_blocks = 1, 2, 32, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    T = q.shape[0]
    qg = q.reshape(T, Hkv, G, D)
    out1 = paged_attention_ragged(qg, kp, vp, jnp.asarray(ttab),
                                  jnp.asarray(tpos), interpret=True)
    out2 = paged_attention_ragged(qg, kp.at[0].set(1e4),
                                  vp.at[0].set(-1e4), jnp.asarray(ttab),
                                  jnp.asarray(tpos), interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_ops_ragged_wrapper_dispatches_to_reference_on_cpu(key):
    """On the CPU backend the wrapper must use the XLA reference path and
    accept the model-native (T, H, D) flat query layout."""
    segments = SEGMENT_MIXES["multi_prefill"]
    Hkv, G, D, bs, max_blocks = 2, 2, 16, 4, 4
    q, kp, vp, tables, ttab, tpos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, jnp.float32)
    out = ops.paged_attention_ragged(q, kp, vp, jnp.asarray(ttab),
                                     jnp.asarray(tpos))
    ref = paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                           jnp.asarray(tpos))
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# segment-tiled generalization: (q_tile, kv_head, kv_block) grid
# ---------------------------------------------------------------------------
# (tile, segments, T_pad): each segment is (start_pos, n_tokens) for one
# lane, packed back to back into a flat stream padded to T_pad.  The mixes
# pin the geometry the tiled grid must survive: q windows straddling
# segment boundaries (segment offsets not multiples of tile), segments
# both smaller and larger than a tile, and start positions straddling KV
# block edges (start % bs != 0 with bs = 4 below).
TILED_MIXES = {
    "straddling_boundaries": (4, [(3, 1), (0, 9), (7, 5), (14, 1)], 16),
    "segments_smaller_than_tile": (16, [(0, 3), (1, 2), (4, 6)], 16),
    "segments_larger_than_tile": (4, [(0, 17), (5, 9)], 32),
    "all_decode": (8, [(2, 1), (5, 1), (9, 1), (0, 1)], 8),
}


def _tiled_setup(key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad,
                 dtype):
    """Pools + per-lane tables + flat-token metadata + the TileMap."""
    q, k_pool, v_pool, tables, token_tables, token_pos = _ragged_setup(
        key, segments, Hkv, G, D, bs, max_blocks, dtype)
    T = q.shape[0]
    if T_pad > T:            # bucket tail: lane-0/pos-0 padding rows
        ks = jax.random.split(key, 2)
        q = jnp.concatenate(
            [q, jax.random.normal(ks[1], (T_pad - T,) + q.shape[1:], dtype)])
        token_tables = np.concatenate(
            [token_tables, np.zeros((T_pad - T, max_blocks), np.int32)])
        token_pos = np.concatenate(
            [token_pos, np.zeros((T_pad - T,), np.int32)])
    offs, lens, lanes, pos0 = [], [], [], []
    off = 0
    for lane, (start, n) in enumerate(segments):
        offs.append(off); lens.append(n); lanes.append(lane)
        pos0.append(start)
        off += n
    tm = build_tile_map(offs, lens, lanes, pos0, T_pad, len(segments), tile)
    return q, k_pool, v_pool, tables, token_tables, token_pos, tm, T


@pytest.mark.parametrize("mix", sorted(TILED_MIXES))
@pytest.mark.parametrize("G", [1, 4, 8])
def test_tiled_reference_matches_per_token_reference(key, mix, G):
    """The segment-tiled oracle must agree with the per-token flat oracle
    on every real row: tiling is a scheduling change, not a math change."""
    tile, segments, T_pad = TILED_MIXES[mix]
    Hkv, D, bs, max_blocks = 2, 16, 4, 8
    q, kp, vp, tables, ttab, tpos, tm, T = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad, jnp.float32)
    per_tok = paged_attention_ragged_reference(
        q, kp, vp, jnp.asarray(ttab), jnp.asarray(tpos))
    tiled = paged_attention_ragged_tiled_reference(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile)
    np.testing.assert_array_equal(np.asarray(tiled[:T]),
                                  np.asarray(per_tok[:T]))
    assert np.all(np.isfinite(np.asarray(tiled)))    # padding rows: finite


@pytest.mark.parametrize("mix", sorted(TILED_MIXES))
@pytest.mark.parametrize("G", [1, 4, 8])
@pytest.mark.parametrize("window", [0, 5])
def test_tiled_kernel_matches_tiled_reference(key, mix, G, window):
    """Pallas segment-tiled kernel (interpret mode) vs the tiled oracle vs
    the per-token oracle, across boundary-straddling tiles, GQA group
    sizes, block-edge positions, and sliding windows."""
    tile, segments, T_pad = TILED_MIXES[mix]
    Hkv, D, bs, max_blocks = 2, 32, 4, 8
    q, kp, vp, tables, ttab, tpos, tm, T = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad, jnp.float32)
    ref_t = paged_attention_ragged_tiled_reference(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile, window=window)
    per_tok = paged_attention_ragged_reference(
        q, kp, vp, jnp.asarray(ttab), jnp.asarray(tpos), window=window)
    H = Hkv * G
    qg = q.reshape(T_pad, Hkv, G, D)
    out = paged_attention_ragged_tiled(
        qg, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile, window=window,
        interpret=True).reshape(T_pad, H, D)
    np.testing.assert_allclose(np.asarray(out[:T]), np.asarray(ref_t[:T]),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out[:T]), np.asarray(per_tok[:T]),
                               atol=2e-5, rtol=2e-5)


def test_tiled_kernel_padding_tiles_are_inert(key):
    """Capacity-padding tiles (lo == hi) and stream-padding rows must not
    change any real row, in kernel or reference — scribbling the null
    block and growing the tile capacity is invisible."""
    tile, segments, T_pad = TILED_MIXES["straddling_boundaries"]
    Hkv, G, D, bs, max_blocks = 2, 2, 16, 4, 8
    q, kp, vp, tables, ttab, tpos, tm, T = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad, jnp.float32)
    qg = q.reshape(T_pad, Hkv, G, D)
    out1 = paged_attention_ragged_tiled(
        qg, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile, interpret=True)
    # double the inert capacity + poison the null block
    meta2 = np.concatenate([tm.meta, np.zeros_like(tm.meta)], axis=1)
    out2 = paged_attention_ragged_tiled(
        qg, kp.at[0].set(1e4), vp.at[0].set(-1e4), jnp.asarray(tables),
        jnp.asarray(meta2), jnp.asarray(tm.row_tile), tile=tile,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out1[:T]), np.asarray(out2[:T]))
    assert np.all(np.isfinite(np.asarray(out2)))
    r1 = paged_attention_ragged_tiled_reference(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile)
    r2 = paged_attention_ragged_tiled_reference(
        q, kp.at[0].set(1e4), vp.at[0].set(-1e4), jnp.asarray(tables),
        jnp.asarray(meta2), jnp.asarray(tm.row_tile), tile=tile)
    np.testing.assert_array_equal(np.asarray(r1[:T]), np.asarray(r2[:T]))


def test_tiled_single_tile_equals_decode_kernel(key):
    """Pure-decode tiles must reproduce the rectangular decode kernel row
    for row (same online-softmax sweep per token)."""
    tile, segments, T_pad = TILED_MIXES["all_decode"]
    Hkv, G, D, bs, max_blocks = 2, 2, 32, 4, 8
    q, kp, vp, tables, ttab, tpos, tm, T = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad, jnp.float32)
    qg = q.reshape(T_pad, Hkv, G, D)
    out = paged_attention_ragged_tiled(
        qg, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile, interpret=True)
    dec = paged_attention(qg[:T], kp, vp, jnp.asarray(ttab[:T]),
                          jnp.asarray(tpos[:T]) + 1, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:T]), np.asarray(dec),
                               atol=2e-5, rtol=2e-5)


def test_ops_tiled_wrapper_dispatches_to_reference_on_cpu(key):
    """On the CPU backend the wrapper must use the tiled XLA reference and
    accept the model-native (T, H, D) flat query layout."""
    tile, segments, T_pad = TILED_MIXES["segments_larger_than_tile"]
    Hkv, G, D, bs, max_blocks = 2, 2, 16, 4, 8
    q, kp, vp, tables, ttab, tpos, tm, T = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T_pad, jnp.float32)
    out = ops.paged_attention_ragged_tiled(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile)
    ref = paged_attention_ragged_tiled_reference(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# instrumented-reference regression: KV gather traffic scales with
# tiles/lanes, not tokens — the fix for the ~30% all-prefill CPU gap
# ---------------------------------------------------------------------------
def test_tiled_reference_gathers_each_block_once_per_lane(key):
    """A 256-token single-segment prefill must read each pool block once
    (one span gather per lane), where the per-token reference reads every
    block once per token — 256x the traffic."""
    T = 256
    tile, bs, max_blocks = 16, 8, 32
    Hkv, G, D = 1, 2, 16
    segments = [(0, T)]
    q, kp, vp, tables, ttab, tpos, tm, _ = _tiled_setup(
        key, segments, Hkv, G, D, bs, max_blocks, tile, T, jnp.float32)
    pool_gather_stats["blocks"] = 0
    paged_attention_ragged_tiled_reference(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
        jnp.asarray(tm.row_tile), tile=tile)
    tiled_reads = pool_gather_stats["blocks"]
    pool_gather_stats["blocks"] = 0
    paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                     jnp.asarray(tpos))
    per_token_reads = pool_gather_stats["blocks"]
    # one lane: k and v pools each gathered once -> each block read once
    assert tiled_reads == 2 * max_blocks
    assert per_token_reads == 2 * T * max_blocks
    assert per_token_reads == T * tiled_reads


def test_tiled_reference_gather_traffic_independent_of_tokens(key):
    """Doubling the scheduled token count must not change the tiled
    reference's pool traffic (it scales with lanes), while the per-token
    reference's doubles."""
    tile, bs, max_blocks = 8, 4, 16
    Hkv, G, D = 2, 2, 16
    counts = {}
    for name, segments in (("short", [(0, 16), (0, 16)]),
                           ("long", [(0, 32), (0, 32)])):
        T = sum(n for _, n in segments)
        q, kp, vp, tables, ttab, tpos, tm, _ = _tiled_setup(
            key, segments, Hkv, G, D, bs, max_blocks, tile, T, jnp.float32)
        pool_gather_stats["blocks"] = 0
        paged_attention_ragged_tiled_reference(
            q, kp, vp, jnp.asarray(tables), jnp.asarray(tm.meta),
            jnp.asarray(tm.row_tile), tile=tile)
        tiled_reads = pool_gather_stats["blocks"]
        pool_gather_stats["blocks"] = 0
        paged_attention_ragged_reference(q, kp, vp, jnp.asarray(ttab),
                                         jnp.asarray(tpos))
        counts[name] = (tiled_reads, pool_gather_stats["blocks"])
    assert counts["long"][0] == counts["short"][0]       # lanes unchanged
    assert counts["long"][1] == 2 * counts["short"][1]   # tokens doubled


def test_tile_map_partitions_real_rows(key):
    """Host-side contract: tiles are disjoint, within-window, within-
    segment slabs whose union is exactly the real token rows."""
    for mix in sorted(TILED_MIXES):
        tile, segments, T_pad = TILED_MIXES[mix]
        offs, lens, lanes, pos0 = [], [], [], []
        off = 0
        for lane, (start, n) in enumerate(segments):
            offs.append(off); lens.append(n); lanes.append(lane)
            pos0.append(start)
            off += n
        tm = build_tile_map(offs, lens, lanes, pos0, T_pad, len(segments),
                            tile)
        total = off
        assert tm.cu_seqlens[0] == 0 and tm.cu_seqlens[-1] == total
        assert np.all(np.diff(tm.cu_seqlens) >= 1)
        covered = np.zeros(total, bool)
        for t in range(tm.n_tiles):
            lo, hi = tm.meta[TILE_LO, t], tm.meta[TILE_HI, t]
            assert lo < hi
            assert lo // tile == (hi - 1) // tile        # one window
            s = np.searchsorted(tm.cu_seqlens, lo, side="right") - 1
            assert hi <= tm.cu_seqlens[s + 1]            # one segment
            assert not covered[lo:hi].any()
            covered[lo:hi] = True
            assert np.all(tm.row_tile[lo:hi] == t)
        assert covered.all()
        for t in range(tm.n_tiles, tm.meta.shape[1]):    # inert capacity
            assert tm.meta[TILE_LO, t] == tm.meta[TILE_HI, t]
