"""Flow engine: deploy/run, parameter references, retries, failure branches,
auth scopes — the paper's §3 semantics."""
import pytest

from repro.core import build_system, dnn_trainer_flow
from repro.core.auth import SCOPE_FLOWS
from repro.core.flows import ActionFailure, ActionProvider, FlowError
from repro.core.transfer import FileRef


def _system_with_dataset(n_files=4, nbytes=10_000_000, **kw):
    sys_ = build_system(**kw)
    for i in range(n_files):
        sys_.store.put("slac", FileRef(f"d{i}", nbytes))
    return sys_


def test_deploy_validates_definition():
    sys_ = build_system()
    with pytest.raises(FlowError):
        sys_.flows.deploy({"StartAt": "Nope", "States": {}})
    with pytest.raises(FlowError):
        sys_.flows.deploy({"StartAt": "A", "States": {
            "A": {"Provider": "transfer", "Next": "Missing"}}})
    with pytest.raises(FlowError):
        sys_.flows.deploy({"StartAt": "A", "States": {
            "A": {"Provider": "not-a-provider", "End": True}}})


def test_full_dnn_trainer_flow_sequence():
    sys_ = _system_with_dataset()
    tok = sys_.user_token()

    def train():
        sys_.store.put("alcf", FileRef("m.npz", 3_000_000, {"w": 1}))
        return {"ok": True}

    fid = sys_.funcx.register_function(train)
    eid = sys_.funcx.register_endpoint("cerebras", mode="modeled")
    flow_id = sys_.flows.deploy(dnn_trainer_flow())
    run = sys_.flows.run(flow_id, {
        "src": "slac", "dc": "alcf", "dataset": [f"d{i}" for i in range(4)],
        "train_endpoint": eid, "train_function": fid,
        "train_args": [], "train_kwargs": {}, "modeled_duration": 19.0,
        "model_artifacts": ["m.npz"], "model_name": "m.npz",
        "register_as": "braggnn", "version_tag": "v1", "metrics": {},
    }, tok)
    assert run.status == "SUCCEEDED"
    assert [e.state for e in run.log] == [
        "TransferData", "TrainModel", "TransferModel", "RegisterModel"]
    assert run.turnaround > 19.0            # includes modeled train
    assert sys_.store.exists("slac", "m.npz")  # model delivered to the edge
    assert sys_.repo.latest("braggnn").version == 1


def test_retry_then_failure_branch():
    sys_ = build_system()
    tok = sys_.user_token()

    calls = {"n": 0}

    class Flaky(ActionProvider):
        name = "flaky"
        required_scope = SCOPE_FLOWS

        def run(self, params, ctx):
            calls["n"] += 1
            raise ActionFailure("always down")

    class Notify(ActionProvider):
        name = "notify"
        required_scope = SCOPE_FLOWS

        def run(self, params, ctx):
            return {"notified": True}

    sys_.flows.providers["flaky"] = Flaky()
    sys_.flows.providers["notify"] = Notify()
    fid = sys_.flows.deploy({
        "StartAt": "Work",
        "States": {
            "Work": {"Provider": "flaky", "Retries": 2,
                     "OnFailure": "Tell", "Next": "Done"},
            "Tell": {"Provider": "notify", "End": True},
            "Done": {"End": True},
        },
    })
    run = sys_.flows.run(fid, {}, tok)
    assert calls["n"] == 3                      # 1 + 2 retries
    assert run.log[0].status == "FAILED"
    assert run.log[1].state == "Tell"
    assert run.status == "SUCCEEDED"            # failure branch handled it


def test_missing_scope_fails_action():
    sys_ = _system_with_dataset(1)
    tok = sys_.auth.issue("limited", [SCOPE_FLOWS])   # no transfer scope
    fid = sys_.flows.deploy({
        "StartAt": "T",
        "States": {"T": {"Provider": "transfer",
                         "Parameters": {"src": "slac", "dst": "alcf",
                                        "names": ["d0"]},
                         "End": True}},
    })
    run = sys_.flows.run(fid, {}, tok)
    assert run.status == "FAILED"
    assert "lacks scope" in run.log[0].error


def test_parameter_references_resolve_across_states():
    sys_ = _system_with_dataset(2)
    tok = sys_.user_token()

    class Echo(ActionProvider):
        name = "echo"
        required_scope = SCOPE_FLOWS

        def run(self, params, ctx):
            return {"value": params["value"]}

    sys_.flows.providers["echo"] = Echo()
    fid = sys_.flows.deploy({
        "StartAt": "A",
        "States": {
            "A": {"Provider": "echo", "Parameters": {"value": "$.input.x"},
                  "Next": "B"},
            "B": {"Provider": "echo",
                  "Parameters": {"value": "$.results.A.value"},
                  "End": True},
        },
    })
    run = sys_.flows.run(fid, {"x": 42}, tok)
    assert run.output["B"]["value"] == 42
