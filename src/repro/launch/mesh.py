"""Production mesh construction (DESIGN.md §5, system-prompt contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # jax < 0.5 has no explicit-sharding axis types
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests / single host)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel),
                      ("data", "model"))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def mesh_slices(mesh):
    """Split a mesh into per-data-slice tensor-parallel sub-meshes.

    A ``(data..., model)`` mesh of dp * tp devices becomes ``dp`` meshes
    of shape ``("data", "model") = (1, tp)`` — one per engine slice of a
    data-parallel serving front.  Each slice keeps the "data" axis (size
    1) so the sharding rule tables resolve identically on a slice and on
    the full mesh.  A mesh with no "model" axis yields pure data slices
    (tp = 1).
    """
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    devs = mesh.devices.reshape(-1, tp)
    from jax.sharding import Mesh
    return [Mesh(devs[i].reshape(1, tp), ("data", "model"))
            for i in range(devs.shape[0])]


def data_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# hardware constants for the roofline model (TPU v5e)
CHIP_PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
CHIP_HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9                 # bytes/s per link (~ per-direction)
