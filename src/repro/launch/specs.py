"""Abstract input specs + step functions for every (arch x shape) combo.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation); the step
builders return the functions the launcher jits:

  * train_4k    -> ``train_step(params, opt_state, batch)``   (the T op)
  * prefill_32k -> ``prefill_step(params, batch)``            (admission)
  * decode_32k / long_500k -> ``serve_step(params, cache, tokens)`` (the E op)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.models import build_model
from repro.models.api import ModelAPI
from repro.optim import adamw

PyTree = Any
SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
def abstract_params(api: ModelAPI) -> PyTree:
    return jax.eval_shape(api.init, SDS((2,), jnp.uint32))


def _decoder_len(cfg: ArchConfig, seq: int) -> int:
    if cfg.is_encoder_decoder and cfg.max_decoder_positions:
        return min(seq, cfg.max_decoder_positions)
    return seq


def batch_abstract(cfg: ArchConfig, shape: InputShape) -> Dict[str, SDS]:
    """Training / prefill batch ShapeDtypeStructs."""
    B = shape.global_batch
    S = shape.seq_len
    if cfg.family == "audio":
        S_dec = _decoder_len(cfg, S)
        return {
            "frames": SDS((B, cfg.encoder_positions, cfg.frontend.d_embed),
                          jnp.bfloat16),
            "tokens": SDS((B, S_dec), jnp.int32),
            "labels": SDS((B, S_dec), jnp.int32),
        }
    if cfg.family == "vlm":
        n_img = cfg.frontend.n_tokens
        S_text = max(S - n_img, 16)
        return {
            "patches": SDS((B, n_img, cfg.frontend.d_embed), jnp.bfloat16),
            "tokens": SDS((B, S_text), jnp.int32),
            "labels": SDS((B, S_text), jnp.int32),
        }
    return {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }


def cache_abstract(api: ModelAPI, shape: InputShape) -> PyTree:
    window = api.effective_window(shape.seq_len)
    return jax.eval_shape(
        functools.partial(api.init_cache, shape.global_batch, shape.seq_len,
                          window=window))


def decode_tokens_abstract(shape: InputShape) -> SDS:
    return SDS((shape.global_batch, 1), jnp.int32)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_loss_for_shape(api: ModelAPI, shape: InputShape, *,
                        attn_chunk: int = 512, remat: bool = True):
    window = api.effective_window(shape.seq_len)
    cfg = api.cfg

    def loss(params, batch):
        kwargs: Dict[str, Any] = dict(window=window, attn_chunk=attn_chunk,
                                      remat=remat)
        if cfg.family == "audio":
            return api.loss(params, batch, **{k: v for k, v in kwargs.items()
                                              if k != "window"})
        return api.loss(params, batch, **kwargs)

    return loss


def make_train_step_fn(api: ModelAPI, shape: InputShape, *,
                       lr: float = 1e-4, attn_chunk: int = 512,
                       remat: bool = True,
                       pre_gather: bool = False) -> Callable:
    loss = make_loss_for_shape(api, shape, attn_chunk=attn_chunk,
                               remat=remat)
    opt = adamw(lr, grad_clip_norm=1.0)

    def _gathered_bf16(tree):
        """§Perf-2: one bf16 all-gather of the FSDP-sharded master weights
        per step (outside the layer scans), instead of per-segment/remat
        re-gathers in fp32.  Differentiable: grads flow through the cast."""
        from jax.sharding import PartitionSpec as P

        def leaf(x):
            if not jnp.issubdtype(x.dtype, jnp.floating):
                return x
            y = x.astype(jnp.bfloat16)
            from repro.models.common import abstract_mesh
            mesh = abstract_mesh()
            if mesh is not None and not mesh.empty:
                y = jax.lax.with_sharding_constraint(
                    y, P(*([None] * y.ndim)))
            return y

        return jax.tree.map(leaf, tree)

    def train_step(params, opt_state, batch):
        def loss_of(p, b):
            return loss(_gathered_bf16(p) if pre_gather else p, b)

        (l, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
            params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = l
        from repro.optim.optimizers import global_norm
        metrics["grad_norm"] = global_norm(grads)
        return new_params, new_opt, metrics

    return train_step, opt


def make_prefill_step_fn(api: ModelAPI, shape: InputShape, *,
                         attn_chunk: int = 512) -> Callable:
    window = api.effective_window(shape.seq_len)
    cfg = api.cfg

    def prefill_step(params, batch):
        if cfg.family == "audio":
            logits, _ = api.forward(params, batch["tokens"],
                                    frames=batch["frames"],
                                    attn_chunk=attn_chunk, remat=False)
        elif cfg.family == "vlm":
            logits, _ = api.forward(params, batch["tokens"],
                                    patches=batch["patches"], window=window,
                                    attn_chunk=attn_chunk, remat=False)
        else:
            logits, _ = api.forward(params, batch["tokens"], window=window,
                                    attn_chunk=attn_chunk, remat=False)
        # serving admission only needs the last position (next-token sampling)
        return logits[:, -1].astype(jnp.bfloat16)

    return prefill_step


def cast_params_bf16(tree):
    """Inference-time parameter dtype (serving uses bf16 checkpoints)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, tree)


def make_serve_step_fn(api: ModelAPI, shape: InputShape) -> Callable:
    window = api.effective_window(shape.seq_len)

    def serve_step(params, cache, tokens):
        return api.decode_step(params, cache, tokens, window=window)

    return serve_step


# ---------------------------------------------------------------------------
def combo_supported(cfg: ArchConfig, shape: InputShape
                    ) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, cfg.long_context_skip_reason or "no long-context path"
    if shape.is_decode and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    return True, ""
