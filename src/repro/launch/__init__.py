# launch layer: mesh construction, sharding rules, dry-run, train/serve CLIs
