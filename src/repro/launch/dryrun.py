"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh and extract roofline inputs.  THE ONLY entry point that
forces 512 placeholder devices — set before any other import.
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, SHAPES  # noqa: E402
from repro.launch import sharding as shard_lib  # noqa: E402
from repro.launch import specs as specs_lib     # noqa: E402
from repro.launch.mesh import (data_axes_of, make_production_mesh,  # noqa: E402
                               mesh_axis_sizes)
from repro.models import build_model            # noqa: E402
from repro.roofline import hlo_parse            # noqa: E402


def count_params(tree) -> int:
    return sum(int(l.size) for l in jax.tree.leaves(tree))


def active_param_count(tree) -> int:
    """MoE-aware active params: expert leaves scale by top-k/E."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                     for x in path)
        n = int(leaf.size)
        if "experts_w" in p:
            # leading dim is the expert count
            total += n  # corrected by caller via cfg ratio
        else:
            total += n
    return total


def moe_active_params(cfg, tree) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        p = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                     for x in path)
        n = int(leaf.size)
        if "experts_w" in p and cfg.moe is not None:
            n = n * cfg.moe.experts_per_token // cfg.moe.n_experts
        total += n
    return total


def tokens_per_step(cfg, shape) -> int:
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        return B
    if cfg.family == "audio":
        return B * (cfg.encoder_positions
                    + specs_lib._decoder_len(cfg, S))
    return B * S


# ---------------------------------------------------------------------------
def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              out_dir: Optional[str] = None, save_hlo: bool = False,
              attn_chunk: int = 512, remat: bool = True,
              moe_impl: Optional[str] = None,
              sharding_policy: str = "baseline",
              tag: str = "", verbose: bool = True) -> Dict[str, Any]:
    import dataclasses as _dc

    cfg = get_config(arch)
    if moe_impl and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, impl=moe_impl))
    shape = get_shape(shape_name)
    ok, reason = specs_lib.combo_supported(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "status": "SKIPPED" if not ok else "PENDING", "skip_reason": reason,
    }
    if not ok:
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        _maybe_save(result, out_dir)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axis_sizes(mesh)
    data_axes = data_axes_of(mesh)
    if sharding_policy in ("fsdp_flat", "replicated"):
        # pure data parallelism: batch shards over the WHOLE mesh (the
        # model axis would otherwise compute redundant replicas)
        data_axes = tuple(a for a in ("pod", "data", "model")
                          if a in axes)
    n_dev = mesh.devices.size
    api = build_model(cfg)

    params_sds = specs_lib.abstract_params(api)
    if shape.kind != "train":
        params_sds = specs_lib.cast_params_bf16(params_sds)
    pspecs = shard_lib.param_specs(params_sds, axes, data_axes,
                                   policy=sharding_policy)
    pshard = shard_lib.to_named(pspecs, mesh)

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            step, opt = specs_lib.make_train_step_fn(
                api, shape, attn_chunk=attn_chunk, remat=remat,
                pre_gather=(sharding_policy == "fsdp_flat"))
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospecs = shard_lib.param_specs(opt_sds, axes, data_axes,
                                           policy=sharding_policy)
            oshard = shard_lib.to_named(ospecs, mesh)
            batch_sds = specs_lib.batch_abstract(cfg, shape)
            bshard = {
                k: jax.sharding.NamedSharding(
                    mesh, shard_lib.batch_spec(v.shape, axes, data_axes))
                for k, v in batch_sds.items()}
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            step = specs_lib.make_prefill_step_fn(api, shape,
                                                  attn_chunk=attn_chunk)
            batch_sds = specs_lib.batch_abstract(cfg, shape)
            bshard = {
                k: jax.sharding.NamedSharding(
                    mesh, shard_lib.batch_spec(v.shape, axes, data_axes))
                for k, v in batch_sds.items()}
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            step = specs_lib.make_serve_step_fn(api, shape)
            cache_sds = specs_lib.cache_abstract(api, shape)
            cspecs = shard_lib.cache_specs(cache_sds, axes, data_axes)
            cshard = shard_lib.to_named(cspecs, mesh)
            tok_sds = specs_lib.decode_tokens_abstract(shape)
            tshard = jax.sharding.NamedSharding(
                mesh, shard_lib.batch_spec(tok_sds.shape, axes, data_axes))
            jitted = jax.jit(step, in_shardings=(pshard, cshard, tshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds)
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    # ---- analyses ---------------------------------------------------------
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # jax < 0.5: one dict per device
        ca = ca[0] if ca else {}
    cost = {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "optimal_seconds", "utilization")}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            a: int(getattr(mem, a))
            for a in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, a)
        }
    except Exception:
        mem_info = {}

    hlo = compiled.as_text()
    coll_flat = hlo_parse.collective_bytes(hlo)
    # loop-aware: while-body collectives execute once per scan iteration
    coll = hlo_parse.collective_bytes_loop_aware(hlo)
    coll_total = sum(v["bytes"] for v in coll.values())

    n_total = count_params(params_sds)
    n_active = moe_active_params(cfg, params_sds)
    toks = tokens_per_step(cfg, shape)
    kind = "train" if shape.kind == "train" else "forward"
    from repro.roofline.analysis import model_flops_estimate
    mf = model_flops_estimate(n_active, toks, kind)

    # analytic FLOPs/bytes accounting (cost_analysis counts loop bodies
    # once on this backend — see roofline/analytic.py docstring)
    from repro.roofline import analytic
    window = api.effective_window(shape.seq_len)
    acct = analytic.step_account(cfg, shape, window=window,
                                 n_params_total=n_total,
                                 n_params_active=n_active, remat=remat)
    acct_out = {k: v for k, v in acct.items() if k != "parts"}
    acct_out["parts"] = {k: float(v) for k, v in acct["parts"].items()}

    result.update({
        "status": "OK",
        "n_devices": n_dev,
        "mesh_axes": axes,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "cost_analysis": cost,
        "memory_analysis": mem_info,
        "bytes_per_device": mem_info.get("temp_size_in_bytes", 0) / max(n_dev, 1),
        "collectives": coll,
        "collectives_flat": coll_flat,
        "collective_bytes_total": coll_total,
        "params_total": n_total,
        "params_active": n_active,
        "tokens_per_step": toks,
        "model_flops": mf,
        "analytic": acct_out,
        "window": window,
        "attn_chunk": attn_chunk,
        "remat": remat,
        "moe_impl": (cfg.moe.impl if cfg.moe else None),
        "sharding_policy": sharding_policy,
        "tag": tag,
    })
    if save_hlo and out_dir:
        os.makedirs(out_dir, exist_ok=True)
        hpath = os.path.join(
            out_dir, f"hlo_{arch}_{shape_name}_{mesh_tag}.txt")
        with open(hpath, "w") as f:
            f.write(hlo)
        result["hlo_path"] = hpath
    if verbose:
        print(f"[dryrun] OK {arch} x {shape_name} x {mesh_tag}: "
              f"flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e} "
              f"coll={coll_total:.3e}B "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {mem_info}")
        print(f"  collectives: "
              + "; ".join(f"{k}:{int(v['count'])}x {v['bytes']:.2e}B"
                          for k, v in sorted(coll.items())))
    _maybe_save(result, out_dir)
    return result


def _maybe_save(result: Dict, out_dir: Optional[str]) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{result['tag']}" if result.get('tag') else ""
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1)


# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="input shape (default: all four)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 dual-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-impl", default=None,
                    help="override MoE dispatch impl (gshard|gather)")
    ap.add_argument("--sharding-policy", default="baseline",
                    help="param sharding policy (see sharding.POLICY_OVERRIDES)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_combo(arch, shape, multi_pod=mp, out_dir=args.out,
                              save_hlo=args.save_hlo,
                              attn_chunk=args.attn_chunk,
                              remat=not args.no_remat,
                              moe_impl=args.moe_impl,
                              sharding_policy=args.sharding_policy,
                              tag=args.tag)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
