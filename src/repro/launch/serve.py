"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the DecodeEngine (continuous batching over a slot grid) on a smoke
variant of the arch and runs a batch of synthetic requests through it —
the edge-side "E" operation as a real process.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import DecodeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    window = api.effective_window(args.cache_len)
    eng = DecodeEngine(api, params, n_slots=args.slots,
                       cache_len=args.cache_len, window=window)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        eng.submit(prompt, args.max_new)
    finished = eng.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} requests={len(finished)} "
          f"engine_steps={eng.steps} tokens={eng.tokens_decoded} "
          f"({eng.tokens_decoded / dt:.1f} tok/s incl. compile)")
    for r in finished[:3]:
        print(f"  req {r.request_id}: {len(r.generated)} tokens, "
              f"first 8 = {r.generated[:8]}")
    assert all(len(r.generated) > 0 for r in finished)
    print("done")


if __name__ == "__main__":
    main()
