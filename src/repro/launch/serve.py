"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the DecodeEngine — paged-KV continuous batching for transformer
families, dense-slot fallback for recurrent ones — on a smoke variant of
the arch and runs a batch of synthetic requests through it — the edge-side
"E" operation as a real process.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import DecodeEngine


EPILOG = """\
mesh serving (CPU smoke — 4 virtual devices, 2 data slices x 2-way
tensor parallel; on real hardware drop XLA_FLAGS and size the mesh to
the accelerators):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.serve --arch gemma-7b --mesh-shape 2,2

  # pure tensor parallelism over every visible device
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.serve --arch gemma-7b --tp 4
"""


def _build_mesh(mesh_shape: str, tp: int):
    """Mesh from --mesh-shape "dp,tp" (first dp*tp devices) or --tp N
    (all devices, model_parallel=N); None when neither is set."""
    if mesh_shape:
        from jax.sharding import Mesh
        dp, tp_ = (int(x) for x in mesh_shape.split(","))
        devs = jax.devices()
        if dp * tp_ > len(devs):
            raise SystemExit(
                f"--mesh-shape {dp},{tp_} needs {dp * tp_} devices, "
                f"found {len(devs)} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={dp * tp_} "
                "for a CPU smoke)")
        grid = np.array(devs[:dp * tp_]).reshape(dp, tp_)
        return Mesh(grid, ("data", "model"))
    if tp:
        from repro.launch.mesh import make_host_mesh
        if len(jax.devices()) % tp:
            raise SystemExit(
                f"--tp {tp} does not divide the {len(jax.devices())} "
                "visible devices")
        return make_host_mesh(model_parallel=tp)
    return None


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per physical KV block (paged engine)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="physical KV pool size; 0 = dense-equivalent")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="max tokens per engine step; "
                         "0 = slots * chunk-tokens")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="max prefill tokens per request per step "
                         "(1 = PR 1 one-token-per-step prefill)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="share identical prompt prefixes copy-on-write "
                         "across requests (--no-prefix-cache disables)")
    ap.add_argument("--ragged", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="flat-token serving batch (one 1-D stream of all "
                         "scheduled tokens per step); --no-ragged pins the "
                         "rectangular (lanes, chunk_width) layout")
    ap.add_argument("--tiled", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="segment-tiled attention grid over the flat "
                         "stream (KV read once per q-tile); --no-tiled "
                         "pins the per-token (token, head, block) grid")
    ap.add_argument("--tile", type=int, default=16,
                    help="q rows per segment tile window (pow2)")
    ap.add_argument("--spec", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="speculative multi-token decode: n-gram drafts "
                         "verified by the step's own argmax, accepted "
                         "prefix + bonus token emitted per step; "
                         "--no-spec pins one-token-per-step decode")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max draft tokens proposed per decode lane per "
                         "step (0 disables speculation)")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="time-to-first-token target (ms) for SLO-aware "
                         "admission: queued requests past the deadline "
                         "are shed instead of admitted (paged engine; "
                         "0 disables)")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help="time-per-output-token target (ms): when the "
                         "decode TPOT EWMA slips past it the scheduler "
                         "shrinks prefill chunks and stops stealing "
                         "lanes for new admissions (0 disables)")
    ap.add_argument("--engine", choices=["auto", "paged", "slot"],
                    default="auto",
                    help="paged block-pool engine vs dense-slot reference")
    ap.add_argument("--mesh-shape", default="",
                    help='"dp,tp" device mesh: dp data-parallel engine '
                         "slices x tp-way tensor-parallel shards each "
                         "(see the epilog for a CPU smoke)")
    ap.add_argument("--tp", type=int, default=0,
                    help="shortcut: tensor-parallel degree over ALL "
                         "visible devices (dp = n_devices / tp)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.mesh_shape and args.tp:
        raise SystemExit("--mesh-shape and --tp are exclusive")
    mesh = _build_mesh(args.mesh_shape, args.tp)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke_variant()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    window = api.effective_window(args.cache_len)
    paged = None if args.engine == "auto" else (args.engine == "paged")
    kw = {}
    if paged is not False and (paged or api.supports_paged):
        kw = {"block_size": args.block_size,
              "num_blocks": args.num_blocks or None,
              "token_budget": args.token_budget,
              "chunk_tokens": args.chunk_tokens,
              "prefix_cache": args.prefix_cache,
              "ragged": args.ragged and api.supports_ragged,
              "tiled": (args.tiled and args.ragged
                        and api.supports_ragged),
              "tile": args.tile,
              "spec": args.spec and api.supports_spec,
              "draft_k": args.draft_k,
              "ttft_target": args.slo_ttft_ms / 1e3,
              "tpot_target": args.slo_tpot_ms / 1e3}
    if mesh is not None:
        kw["mesh"] = mesh
    eng = DecodeEngine(api, params, paged=paged, n_slots=args.slots,
                       cache_len=args.cache_len, window=window, **kw)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        eng.submit(prompt, args.max_new)
    finished = eng.run_until_drained()
    dt = time.perf_counter() - t0
    shed = sum(1 for r in finished if getattr(r, "shed", False))
    print(f"arch={cfg.name} engine={type(eng).__name__} "
          f"requests={len(finished)} shed={shed} "
          f"engine_steps={eng.steps} tokens={eng.tokens_decoded} "
          f"({eng.tokens_decoded / dt:.1f} tok/s incl. compile)")
    print(f"  stats: {eng.stats()}")
    for r in finished[:3]:
        print(f"  req {r.request_id}: {len(r.generated)} tokens, "
              f"first 8 = {r.generated[:8]}")
    assert all(len(r.generated) > 0 for r in finished
               if not getattr(r, "shed", False))
    print("done")


if __name__ == "__main__":
    main()
