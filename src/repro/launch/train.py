"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training steps.  Two modes:
  * default — reduced (smoke) variant of the arch on the host devices,
    demonstrating the full pjit path end-to-end on this container;
  * ``--full`` — the full config (only sensible on a real TPU pod slice).

The mesh is built over whatever devices exist (``make_host_mesh``), with the
same sharding rules as the production dry-run — the code path is identical,
only the mesh shape differs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import InputShape
from repro.data.synthetic import lm_token_batch
from repro.launch import sharding as shard_lib
from repro.launch import specs as specs_lib
from repro.launch.mesh import data_axes_of, make_host_mesh, mesh_axis_sizes
from repro.models import build_model
from repro.train import checkpoint as ckpt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke_variant()
    api = build_model(cfg)
    shape = InputShape("cli_train", args.seq, args.batch, "train")

    mesh = make_host_mesh(args.model_parallel)
    axes = mesh_axis_sizes(mesh)
    data_axes = data_axes_of(mesh)
    print(f"mesh {dict(axes)}; arch {cfg.name} ({cfg.family}); "
          f"L={cfg.n_layers} d={cfg.d_model}")

    key = jax.random.PRNGKey(0)
    with jax.set_mesh(mesh):
        params = api.init(key)
        pspecs = shard_lib.param_specs(params, axes, data_axes)
        params = jax.device_put(params, shard_lib.to_named(pspecs, mesh))

        step_fn, opt = specs_lib.make_train_step_fn(api, shape, lr=args.lr)
        opt_state = opt.init(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        t0 = time.perf_counter()
        losses = []
        for step in range(1, args.steps + 1):
            bkey = jax.random.fold_in(key, step)
            batch = lm_token_batch(bkey, args.batch, args.seq,
                                   cfg.vocab_size)
            if cfg.family == "audio":
                batch["frames"] = jax.random.normal(
                    bkey, (args.batch, cfg.encoder_positions,
                           cfg.frontend.d_embed), jnp.bfloat16)
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    bkey, (args.batch, cfg.frontend.n_tokens,
                           cfg.frontend.d_embed), jnp.bfloat16)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % args.log_every == 0 or step == 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):.3f}  "
                      f"({(time.perf_counter() - t0) / step:.3f}s/step)")
        assert np.isfinite(losses[-1]), "training diverged"
        if args.ckpt_dir:
            ckpt_lib.save_checkpoint(args.ckpt_dir, args.steps,
                                     {"params": params})
            print(f"checkpoint saved to {args.ckpt_dir}")
    print("done")


if __name__ == "__main__":
    main()
