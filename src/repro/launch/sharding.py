"""Sharding rules: parameter PartitionSpecs + batch/cache specs per arch.

Logical roles on the production mesh (DESIGN.md §5):
  * "data"  — batch / FSDP axis (16-way per pod; with multi-pod, batch maps
              to ("pod", "data"));
  * "model" — tensor / expert / head axis (16-way).

Rules are (leaf-name regex, dims-from-end axis preferences).  Every
assignment is validated for divisibility against the actual leaf shape and
degrades gracefully (axis dropped) when a dim doesn't divide — this is what
lets ONE rule table cover all 10 architectures (e.g. kv-head sharding
degrades to replication for GQA configs whose 4 kv heads don't split 16
ways, while the 128-dim head size still FSDP-shards).

Axis preference entries may be tuples of alternatives: the first axis (or
axis-tuple) that divides the dim wins.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any
AxisChoice = Union[None, str, Tuple[str, ...]]

# dims counted FROM THE END of the leaf shape; leading (layer-stack) dims
# are automatically unsharded.
#   entry = list of alternatives tried in order; each alternative is an axis
#   name or tuple of axis names (mapped jointly).
RULES: List[Tuple[str, Tuple[Sequence[AxisChoice], ...]]] = [
    # --- MoE ---------------------------------------------------------------
    (r"experts_w_(gate|up)$", (["model"], ["data"], [None])),   # (E, d, h)
    (r"experts_w_down$", (["model"], [None], ["data"])),        # (E, h, d)
    (r"router$", ([None], ["model"])),                          # (d, E)
    # --- attention -----------------------------------------------------------
    (r"\bwq$", (["data"], ["model"], [None])),                  # (d, H, D)
    # kv heads that don't divide the model axis REPLICATE (never D-shard:
    # a sharded contraction dim turns every score matmul into an
    # all-reduce — §Perf-4)
    (r"\bw(k|v)$", (["data"], ["model", None], [None])),        # (d,Hkv,D)
    (r"\bwo$", (["model"], [None], ["data"])),                  # (H, D, d)
    (r"b(q|k|v)$", (["model", None], [None])),                  # (H, D)
    # --- MLP ------------------------------------------------------------------
    (r"w_(gate|up|z)$", (["data"], ["model"])),                 # (d, ff)
    (r"(w_down|ffn_down)$", (["model"], ["data"])),             # (ff, d)
    (r"ffn_(gate|up)$", (["data"], ["model"])),
    (r"b_up$", (["model"],)),
    # --- embeddings / head ------------------------------------------------------
    (r"\bembedding$", (["model"], ["data"])),                   # (V, d)
    (r"head/w$|head.*\bw$", (["data"], ["model"])),             # (d, V)
    (r"dec_pos$", ([None], ["model", "data", None])),
    (r"frame_proj$|projector/w1$", ([None], ["model", "data", None])),
    (r"projector/w2$", (["model", "data", None], [None])),
    # --- mamba2 --------------------------------------------------------------
    (r"mamba/w_in$|\bw_in$", (["data"], ["model"])),            # (d, big)
    (r"conv_w$", ([None], ["model", "data", None])),            # (K, Cd)
    (r"conv_b$", (["model", "data", None],)),
    (r"mamba/w_out$", (["model"], ["data"])),                   # (d_in, d)
    (r"norm_scale$", (["model", "data", None],)),
    # --- xlstm ----------------------------------------------------------------
    (r"w_if$", (["data"], [None])),                             # (d_in, 2H)
    (r"r_gates$", ([None], ["model", None], [None])),           # (4,H,hd,hd)
    (r"w_gates$", (["data"], ["model", "data", None])),         # (d, 4d)
    (r"out_norm_scale$", (["model", "data", None],)),
]

_DEFAULT: Tuple[Sequence[AxisChoice], ...] = ((None,),)

# §Perf sharding-policy overrides, prepended to RULES (first match wins).
POLICY_OVERRIDES: Dict[str, List[Tuple[str, Tuple[Sequence[AxisChoice], ...]]]] = {
    # paper-faithful baseline
    "baseline": [],
    # §Perf-3 (small models): pure data parallelism — replicate every
    # parameter, batch over ("pod","data"); grads reduce once per step.
    "replicated": [(r".", ((None,), (None,), (None,), (None,), (None,)))],
    # §Perf-2 (recurrent stacks): keep FSDP for the big projections but
    # replicate everything the per-timestep sLSTM scan body touches, so the
    # 4096-iteration loop is collective-free.
    "local_recurrent": [
        (r"r_gates$", ((None,), (None,), (None,), (None,))),
        (r"w_gates$", (["data"], (None,))),
        (r"b_gates$", ((None,),)),
    ],
}


def _axis_size(mesh_axes: Dict[str, int], choice: AxisChoice) -> int:
    if choice is None:
        return 1
    if isinstance(choice, tuple):
        n = 1
        for a in choice:
            n *= mesh_axes[a]
        return n
    return mesh_axes[choice]


def spec_for_leaf(path: str, shape: Tuple[int, ...],
                  mesh_axes: Dict[str, int],
                  data_axes: Tuple[str, ...] = ("data",),
                  policy: str = "baseline") -> P:
    """Build a PartitionSpec for one leaf by rule table + divisibility."""
    prefs: Optional[Tuple[Sequence[AxisChoice], ...]] = None
    for pattern, p in POLICY_OVERRIDES.get(policy, []) + RULES:
        if re.search(pattern, path):
            prefs = p
            break
    if prefs is None:
        prefs = _DEFAULT

    ndim = len(shape)
    spec: List[AxisChoice] = [None] * ndim
    used: set = set()
    # apply from the end
    for k, alternatives in enumerate(prefs):
        dim = ndim - len(prefs) + k
        if dim < 0:
            continue
        for alt in alternatives:
            if alt is None:
                break
            # expand "data" to the full batch axes tuple (e.g. pod+data)
            cand: AxisChoice = alt
            if alt == "data" and len(data_axes) > 1:
                cand = tuple(data_axes)
            names = cand if isinstance(cand, tuple) else (cand,)
            if any(n in used for n in names):
                continue
            # an axis the mesh doesn't carry can't be assigned (e.g. a
            # serving mesh restricted to {"model": tp} skips every "data"
            # alternative instead of KeyError-ing)
            if any(n not in mesh_axes for n in names):
                continue
            if shape[dim] % _axis_size(mesh_axes, cand) == 0:
                spec[dim] = cand
                used.update(names)
                break

    # §Perf-4: attention projections whose head dim cannot take the model
    # axis must REPLICATE outright — keeping the d-dim FSDP-sharded makes
    # XLA partial-reduce the (replicated-batch) activations instead of
    # gathering the small weight (observed: 455 s of all-reduce on
    # starcoder2 36H/4kv prefill).
    if re.search(r"\bw[qkvo]$", path) and not any(
            s == "model" or (isinstance(s, tuple) and "model" in s)
            for s in spec):
        return P(*([None] * ndim))
    return P(*spec)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _fsdp_flat_spec(shape: Tuple[int, ...],
                    mesh_axes: Dict[str, int]) -> P:
    """§Perf-2 policy: weight STORAGE sharded over the whole mesh (one dim
    over ("pod","data","model") combined), weights gathered at use, compute
    purely data-parallel — no model-parallel activation collectives.  The
    right regime for models whose head structure doesn't divide the model
    axis (xlstm's 4 heads vs a 16-way axis)."""
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
    # small leaves stay replicated: sharding them buys nothing and makes
    # their in-scan gradient contributions psum per iteration (§Perf-2 it.5)
    n_elem = 1
    for s in shape:
        n_elem *= s
    if n_elem < (1 << 23):
        return P(*([None] * len(shape)))
    # try combined suffixes then single axes, largest dim first
    dims = sorted(range(len(shape)), key=lambda i: -shape[i])
    for k in range(len(all_axes), 0, -1):
        axes = all_axes[-k:]
        n = 1
        for a in axes:
            n *= mesh_axes[a]
        for dim in dims:
            if shape[dim] % n == 0 and shape[dim] >= n:
                spec: List[AxisChoice] = [None] * len(shape)
                spec[dim] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
    return P(*([None] * len(shape)))


def param_specs(tree: PyTree, mesh_axes: Dict[str, int],
                data_axes: Tuple[str, ...] = ("data",),
                policy: str = "baseline") -> PyTree:
    """Specs for a whole parameter / optimizer-state tree."""
    def per_leaf(path, leaf):
        shape = tuple(leaf.shape)
        if policy == "fsdp_flat":
            return _fsdp_flat_spec(shape, mesh_axes)
        return spec_for_leaf(_path_str(path), shape, mesh_axes, data_axes,
                             policy)

    return jax.tree_util.tree_map_with_path(per_leaf, tree)


def serving_param_specs(tree: PyTree, mesh_axes: Dict[str, int]) -> PyTree:
    """Tensor-parallel-only parameter specs for the serving engines.

    Decode batches are a handful of lanes, so the FSDP/batch ("data",
    "pod") placements the training rules prefer would gather weights every
    step for nothing.  Restricting the visible mesh to ``{"model": tp}``
    makes :func:`spec_for_leaf` skip every data alternative (missing axes
    are never assigned) while keeping the full rule table — including the
    GQA degradation that replicates wk/wv whose kv heads don't divide the
    model axis.
    """
    tp_axes = {"model": mesh_axes.get("model", 1)}
    return param_specs(tree, tp_axes, data_axes=())


def paged_pool_specs(cache: PyTree, mesh_axes: Dict[str, int]) -> PyTree:
    """Specs for a paged-KV serving cache (engine ``init_paged_cache``).

    The 5-D K/V pools ``(layers, num_blocks, block_size, Hkv, D)`` shard
    their kv-head dim over "model" — the block axis must stay whole on
    every shard so block tables, CoW copies, and transfer import/export
    address the same physical block ids everywhere (the per-shard pool
    invariant).  When Hkv doesn't divide the axis (GQA), the pool
    replicates — matching the wk/wv degradation so the scattered K/V and
    the pool agree.  Everything else (block tables, per-token metadata)
    replicates: it is tiny host-built bookkeeping every shard must see
    whole.

    The generic :func:`cache_specs` is wrong here on purpose-built
    grounds: it targets dense ``(L, B, S, Hkv, D)`` slabs and would shard
    the block_size dim of a paged pool.
    """
    m = mesh_axes.get("model", 1)

    def per_leaf(leaf):
        shape = tuple(leaf.shape)
        spec: List[AxisChoice] = [None] * len(shape)
        if (len(shape) == 5 and jnp.issubdtype(leaf.dtype, jnp.floating)
                and shape[-2] % m == 0 and shape[-2] >= m):
            spec[-2] = "model"
        return P(*spec)

    return jax.tree.map(per_leaf, cache)


# ---------------------------------------------------------------------------
def batch_spec(shape: Tuple[int, ...], mesh_axes: Dict[str, int],
               data_axes: Tuple[str, ...] = ("data",)) -> P:
    """Shard the leading (batch) dim over the batch axes if divisible;
    degrade to fewer axes (then replication) for small batches."""
    b = shape[0]
    for k in range(len(data_axes), 0, -1):
        axes = tuple(data_axes[-k:])
        n = 1
        for a in axes:
            n *= mesh_axes[a]
        if b % n == 0:
            ax: AxisChoice = axes if len(axes) > 1 else axes[0]
            return P(ax, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_specs(tree: PyTree, mesh_axes: Dict[str, int],
                data_axes: Tuple[str, ...] = ("data",)) -> PyTree:
    """KV-cache / recurrent-state specs.

    Heuristic per leaf: find the largest shardable dim among {batch-like,
    slot-like, head-like} — batch dims map to data axes, trailing
    (head/feature) dims to "model" when divisible.  Leaves are e.g.
    k/v (L, B, S, Hkv, D), ssm state (seg, per, B, H, P, N), positions.
    """
    def per_leaf(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        spec: List[AxisChoice] = [None] * ndim
        p = _path_str(path)
        if ndim == 0:
            return P()
        # integer bookkeeping (positions) — replicate
        if leaf.dtype in (jnp.int32, jnp.int64):
            return P(*spec)
        # batch-ish dim: first dim whose size divides the data axes product
        placed_data = False
        data_dim = -1
        for k in range(len(data_axes), 0, -1):
            axes = tuple(data_axes[-k:])
            n = 1
            for a in axes:
                n *= mesh_axes[a]
            for dim in range(ndim - 1):
                if shape[dim] % n == 0 and shape[dim] >= n:
                    spec[dim] = axes if len(axes) > 1 else axes[0]
                    placed_data = True
                    data_dim = dim
                    break
            if placed_data:
                break
        # model axis: KV caches (…, B, S, Hkv, D) shard the SLOT dim S
        # (flash-decode style: per-shard partial softmax, tiny stat merge) —
        # never the head_dim D (a sharded contraction dim turns every
        # decode score into an activation all-reduce, §Perf-4); heads only
        # when they divide.
        m = mesh_axes.get("model", 1)
        candidates = []
        if ndim >= 4:
            candidates = [ndim - 3, ndim - 2]      # slots, then kv heads
        elif ndim >= 2:
            candidates = [ndim - 2]
        for dim in candidates:
            if dim <= data_dim or dim < 0 or spec[dim] is not None:
                continue
            if shape[dim] % m == 0 and shape[dim] >= m:
                spec[dim] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(per_leaf, tree)


def to_named(tree_specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def maybe_shard(x: jax.Array, spec: P) -> jax.Array:
    """Sharding constraint that no-ops when no mesh is active (CPU tests)."""
    from repro.models.common import abstract_mesh
    mesh = abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    ok = all(
        (a is None) or all(n in names for n in (a if isinstance(a, tuple)
                                                else (a,)))
        for a in spec)
    if not ok:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
