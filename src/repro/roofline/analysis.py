"""Roofline analysis from compiled dry-run artifacts (system contract §g).

Per (arch x shape x mesh):
    compute_term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_term     = HLO_bytes / (chips * HBM_bw)
    collective_term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
all devices); collective_bytes from the HLO text parse (per-device output
shapes summed over ops, x chips to globalize).  MODEL_FLOPS = 6*N*D for
training (3x forward for fwd+bwd), 2*N_active*D for single forward/decode.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.launch.mesh import (CHIP_HBM_BW, CHIP_PEAK_FLOPS_BF16,
                               ICI_LINK_BW)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes_per_dev: float
    model_flops: float
    peak_flops: float = CHIP_PEAK_FLOPS_BF16
    hbm_bw: float = CHIP_HBM_BW
    link_bw: float = ICI_LINK_BW

    @property
    def compute_term(self) -> float:
        return self.hlo_flops / (self.n_chips * self.peak_flops)

    @property
    def memory_term(self) -> float:
        return self.hlo_bytes / (self.n_chips * self.hbm_bw)

    @property
    def collective_term(self) -> float:
        # collective bytes are already per-device traffic
        return self.collective_bytes_per_dev / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste indicator."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def mfu_upper_bound(self) -> float:
        """Model-FLOPs utilization if the dominant term were achieved."""
        t = self.step_time_lower_bound
        return self.model_flops / (self.n_chips * self.peak_flops * max(t, 1e-12))

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes_dev": self.collective_bytes_per_dev,
            "model_flops": self.model_flops,
            "t_compute": self.compute_term,
            "t_memory": self.memory_term,
            "t_collective": self.collective_term,
            "dominant": self.dominant,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_upper_bound,
        }


# ---------------------------------------------------------------------------
def model_flops_estimate(n_params_active: int, tokens: int,
                         kind: str) -> float:
    """6*N*D for train, 2*N*D for forward-only (per step)."""
    per_tok = 6 if kind == "train" else 2
    return float(per_tok) * n_params_active * tokens


def from_artifact(art: Dict) -> RooflineTerms:
    """Prefer the analytic FLOPs/bytes (loop-trip-count-correct; validated
    against cost_analysis on loop-free configs) with raw cost_analysis kept
    in the artifact for reference."""
    acct = art.get("analytic", {})
    flops = acct.get("flops") or art["cost_analysis"].get("flops", 0.0)
    bytes_ = acct.get("bytes") or art["cost_analysis"].get(
        "bytes accessed", 0.0)
    return RooflineTerms(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        n_chips=art["n_devices"],
        hlo_flops=flops,
        hlo_bytes=bytes_,
        collective_bytes_per_dev=art["collective_bytes_total"],
        model_flops=art["model_flops"],
    )


def load_artifact(path: str) -> RooflineTerms:
    with open(path) as f:
        return from_artifact(json.load(f))
