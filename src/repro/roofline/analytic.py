"""Analytic FLOPs/bytes accounting per (arch x shape x step kind).

WHY THIS EXISTS (see EXPERIMENTS.md §Roofline notes): XLA's
``compiled.cost_analysis()`` counts while-loop bodies ONCE on this backend,
so any scan-over-layers / chunked-attention program under-reports FLOPs by
the loop trip counts.  This module computes the same quantities analytically
from the architecture config — faithful to the *implementation* (it counts
the GShard one-hot dispatch einsums of the MoE layer, banded-attention work,
remat recompute, optimizer traffic), not just 6*N*D — and is cross-validated
against ``cost_analysis()`` on loop-free (1-layer, full-attention, no-remat)
configs in tests/test_roofline_analytic.py.

Conventions:
  * FLOPs: one multiply-add = 2 FLOPs; global (all devices).
  * bytes: global HBM traffic estimate: parameter reads (+ optimizer
    update traffic for training), activation reads/writes at layer
    boundaries, attention score/band traffic, KV-cache traffic for decode.
  * training multiplier: fwd=1, bwd=2, remat recompute=+1 -> 4x forward
    FLOPs with remat on (3x without).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape

BF16 = 2
F32 = 4
# activation traffic constant: reads+writes of the residual stream per block
ACT_RW = 6


@dataclasses.dataclass
class Account:
    flops: float = 0.0
    bytes: float = 0.0

    def add(self, flops: float = 0.0, bytes: float = 0.0) -> None:
        self.flops += flops
        self.bytes += bytes


# ---------------------------------------------------------------------------
# per-component forward FLOPs for ONE token (batch/seq multiplied by caller)
# ---------------------------------------------------------------------------
def _attn_proj_flops(cfg: ArchConfig) -> float:
    d, H, Hkv, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    return 2 * d * (H * Dh) * 2 + 2 * d * (Hkv * Dh) * 2  # q,o + k,v


def _attn_score_flops_per_token(cfg: ArchConfig, seq: int, window: int,
                                kind: str, cache_len: int = 0) -> float:
    """scores + attn*V flops per query token."""
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    if kind == "decode":
        attended = min(window, cache_len) if window else cache_len
    elif window and window < seq:
        # banded schedule: each q chunk sees a (window + chunk) band
        attended = window
    else:
        attended = seq / 2  # causal average
    return 2 * 2 * attended * H * Dh


def _mlp_flops(cfg: ArchConfig, d_ff: int) -> float:
    n_mat = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    return n_mat * 2 * cfg.d_model * d_ff


def _moe_flops_per_token(cfg: ArchConfig, group_tokens: int) -> Dict[str, float]:
    """Per-token MoE flops, split into parts (dispatch einsums included —
    the GShard one-hot dispatch is real MACs in the baseline program)."""
    mo = cfg.moe
    d = cfg.d_model
    E, k, de = mo.n_experts, mo.experts_per_token, mo.d_expert
    import math
    C = max(1, math.ceil(group_tokens * k / E * mo.capacity_factor))
    expert = 3 * 2 * d * de * k          # gate/up/down on k active experts
    router = 2 * d * E
    if mo.impl == "gather":
        # §Perf-1 gather dispatch: routing is integer gathers/scatters — no
        # MACs; only the k-way weighted combine remains.
        dispatch = 2 * k * d
    else:
        # dispatch + combine einsums 'gsec,gsd->egcd' / 'gsec,egcd->gsd':
        # total = 2 x (2 * G*S*E*C*d); per token = 4*E*C*d.  Since
        # C ~ S*k*cf/E this is an O(S) per-token (O(S^2) per step) GShard
        # dispatch penalty — the prime §Perf-1 target.
        dispatch = 4 * E * C * d
    shared = (3 * 2 * d * de * mo.n_shared_experts
              if mo.n_shared_experts else 0.0)
    return {"expert": expert, "router": router, "dispatch": dispatch,
            "shared": shared, "_capacity": C}


def _mamba_flops_per_token(cfg: ArchConfig) -> float:
    from repro.models.ssm import dims as ssm_dims
    dm = ssm_dims(cfg)
    d, d_in, H, P, N, G = (cfg.d_model, dm["d_inner"], dm["H"], dm["P"],
                           dm["N"], dm["G"])
    Q = cfg.ssm.chunk_size
    proj = 2 * d * (2 * d_in + 2 * G * N + H) + 2 * d_in * d
    conv = 2 * cfg.ssm.d_conv * (d_in + 2 * G * N)
    # SSD intra-chunk: CB (Q*N per token-pair) + (CB*L)@x: per token ~
    #   2*Q*N (scores) + 2*Q*P ... per head
    intra = H * (2 * Q * N + 2 * Q * P)
    # inter-chunk state update + output: 2*P*N per head, twice
    inter = H * (2 * 2 * P * N)
    return proj + conv + intra + inter


def _mlstm_flops_per_token(cfg: ArchConfig, chunk: int = 128) -> float:
    from repro.models.xlstm import mlstm_dims
    dm = mlstm_dims(cfg)
    d, d_in, H, hd = cfg.d_model, dm["d_in"], dm["H"], dm["hd"]
    Q = chunk
    proj = 2 * d * d_in * 2 + 3 * 2 * d_in * d_in + 2 * d_in * d \
        + 2 * d_in * 2 * H
    conv = 2 * 4 * d_in
    intra = H * (2 * Q * hd * 2)          # qk^T and SV within chunk
    inter = H * (2 * 2 * hd * hd)         # state read + update
    return proj + conv + intra + inter


def _slstm_flops_per_token(cfg: ArchConfig) -> float:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ff = int(d * cfg.xlstm.slstm_proj_factor)
    gates = 2 * d * 4 * d                 # input gate projections
    rec = 4 * 2 * H * hd * hd             # recurrent block-diag matmuls
    ffn = 3 * 2 * d * ff
    return gates + rec + ffn


# ---------------------------------------------------------------------------
def forward_flops(cfg: ArchConfig, shape: InputShape, *,
                  window: int, tokens: int) -> Dict[str, float]:
    """Global forward FLOPs for one step, by component."""
    parts: Dict[str, float] = {}
    S = shape.seq_len
    kind = shape.kind
    cache_len = S if kind == "decode" else 0
    d, V = cfg.d_model, cfg.vocab_size
    layout = cfg.block_layout()

    attn_layers = sum(1 for b in layout if "attn" in b)
    mamba_layers = sum(1 for b in layout if b.startswith("mamba2"))
    mlstm_layers = sum(1 for b in layout if b == "mlstm")
    slstm_layers = sum(1 for b in layout if b == "slstm")

    if attn_layers:
        per_tok = (_attn_proj_flops(cfg)
                   + _attn_score_flops_per_token(cfg, S, window, kind,
                                                 cache_len))
        parts["attention"] = attn_layers * per_tok * tokens
        if cfg.moe is not None:
            mo = cfg.moe
            n_moe = attn_layers - mo.first_dense_layers
            group_tokens = 1 if kind == "decode" else S
            mf = _moe_flops_per_token(cfg, group_tokens)
            parts["moe_expert"] = n_moe * (mf["expert"] + mf["shared"]
                                           + mf["router"]) * tokens
            parts["moe_dispatch"] = n_moe * mf["dispatch"] * tokens
            if mo.first_dense_layers:
                dff = mo.dense_d_ff or mo.d_expert
                parts["mlp"] = (mo.first_dense_layers
                                * _mlp_flops(cfg, dff) * tokens)
        elif cfg.d_ff:
            parts["mlp"] = attn_layers * _mlp_flops(cfg, cfg.d_ff) * tokens

    if mamba_layers:
        parts["mamba"] = mamba_layers * _mamba_flops_per_token(cfg) * tokens
    if mlstm_layers:
        parts["mlstm"] = mlstm_layers * _mlstm_flops_per_token(cfg) * tokens
    if slstm_layers:
        parts["slstm"] = slstm_layers * _slstm_flops_per_token(cfg) * tokens

    # encoder (whisper): bidirectional attention over fixed 1500 positions
    if cfg.is_encoder_decoder:
        enc_tok = shape.global_batch * cfg.encoder_positions
        per_tok = (_attn_proj_flops(cfg)
                   + 2 * 2 * cfg.encoder_positions * cfg.n_heads
                   * cfg.resolved_head_dim)
        parts["encoder"] = cfg.n_encoder_layers * (
            per_tok + _mlp_flops(cfg, cfg.d_ff)) * enc_tok
        # cross attention in every decoder layer
        parts["cross_attn"] = cfg.n_layers * (
            2 * 2 * cfg.encoder_positions * cfg.n_heads
            * cfg.resolved_head_dim + _attn_proj_flops(cfg) / 2) * tokens

    if cfg.frontend is not None and cfg.frontend.kind == "image_patches":
        n_img = cfg.frontend.n_tokens * shape.global_batch
        if kind != "decode":
            parts["projector"] = (2 * cfg.frontend.d_embed * d
                                  + 2 * d * d) * n_img

    parts["lm_head"] = 2 * d * V * tokens
    parts["embed"] = 0.0  # gather, no MACs
    return parts


def step_account(cfg: ArchConfig, shape: InputShape, *, window: int,
                 n_params_total: int, n_params_active: int,
                 remat: bool = True) -> Dict[str, float]:
    """Full-step FLOPs + bytes for the shape's step kind."""
    S, B = shape.seq_len, shape.global_batch
    kind = shape.kind
    if kind == "decode":
        tokens = B
    elif cfg.family == "audio":
        tokens = B * min(S, cfg.max_decoder_positions or S)
    elif cfg.family == "vlm":
        tokens = B * S      # image tokens + text tokens fill seq_len
    else:
        tokens = B * S

    parts = forward_flops(cfg, shape, window=window, tokens=tokens)
    fwd = sum(parts.values())

    if kind == "train":
        mult = 4.0 if remat else 3.0
        flops = fwd * mult
        # bytes: params bf16 read fwd+bwd(+remat) + grads f32 write +
        # optimizer (read p,m,v + write p,m,v in f32) + activation traffic
        reads = (3 if remat else 2) * n_params_active * BF16
        opt = 6 * n_params_total * F32 + 2 * n_params_total * F32
        act = tokens * cfg.d_model * len(cfg.block_layout()) * ACT_RW * BF16
        bytes_ = reads + opt + act
    elif kind == "prefill":
        flops = fwd
        bytes_ = (n_params_active * BF16
                  + tokens * cfg.d_model * len(cfg.block_layout())
                  * ACT_RW * BF16)
    else:  # decode
        flops = fwd
        # decode is memory-bound: full active params stream per step +
        # KV-cache / state read
        layout = cfg.block_layout()
        attn_layers = sum(1 for b in layout if "attn" in b)
        slots = min(window, S) if window else S
        kv_bytes = (attn_layers * B * slots * cfg.n_kv_heads
                    * cfg.resolved_head_dim * 2 * BF16)
        state_bytes = 0.0
        if cfg.ssm is not None:
            from repro.models.ssm import dims as ssm_dims
            dm = ssm_dims(cfg)
            n_mamba = sum(1 for b in layout if b.startswith("mamba2"))
            state_bytes = n_mamba * B * dm["H"] * dm["P"] * dm["N"] * F32 * 2
        if cfg.xlstm is not None:
            from repro.models.xlstm import mlstm_dims
            dm = mlstm_dims(cfg)
            n_ml = sum(1 for b in layout if b == "mlstm")
            state_bytes = n_ml * B * dm["H"] * dm["hd"] * dm["hd"] * F32 * 2
        bytes_ = n_params_active * BF16 + kv_bytes + state_bytes
    return {"flops": flops, "bytes": bytes_, "fwd_flops": fwd,
            "parts": parts, "tokens": tokens}
