from repro.roofline.analysis import RooflineTerms, from_artifact, load_artifact  # noqa: F401
from repro.roofline import analytic, hlo_parse  # noqa: F401
