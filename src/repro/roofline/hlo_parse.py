"""Parse compiled HLO text for collective ops and their operand bytes.

``cost_analysis()`` does not report collective bytes, so we sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the optimized HLO module (post-SPMD-partitioning, so
shapes are per-device and replica_groups describe the participating rings).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.:  %x = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %y), ...
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"((?:-start|-done)?)\(",
    re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every tensor literal in a shape string (handles
    tuples like (f32[4,8], u32[])."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {"count": n, "bytes": output_bytes_sum}}.

    Bytes counted are the (per-device) OUTPUT shape of each collective —
    for all-gather that's the gathered result, for all-reduce the reduced
    tensor, a consistent proxy for link traffic per device.
    ``-start`` ops are counted; their ``-done`` twins are skipped.
    """
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0})
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # -done repeats the -start shape
        b = _shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return dict(out)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_bytes(hlo_text).values())


def collective_summary_lines(hlo_text: str) -> List[str]:
    info = collective_bytes(hlo_text)
    return [f"{k}: count={int(v['count'])} bytes={v['bytes']:.3e}"
            for k, v in sorted(info.items())]


# ---------------------------------------------------------------------------
# Loop-aware accounting: a collective inside a while body executes once per
# iteration, so body contributions must be multiplied by the loop trip count
# (extracted from the s32 bound constant in the condition computation).
# ---------------------------------------------------------------------------
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> its text block."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        if line and not line.startswith(" ") and ("{" in line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur_name = m.group(1)
                cur_lines = [line]
                comps[cur_name] = ""
                continue
        if cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
    return comps


def _trip_count(cond_text: str) -> int:
    consts = [int(m.group(1)) for m in _TRIP_RE.finditer(cond_text)]
    return max(consts) if consts else 1


def collective_bytes_loop_aware(hlo_text: str, entry_hint: str = "main"
                                ) -> Dict[str, Dict[str, float]]:
    """Like :func:`collective_bytes` but multiplies while-body contributions
    by the loop trip count (recursively, for nested scans)."""
    comps = _split_computations(hlo_text)
    entry = None
    for name in comps:
        if entry_hint in name:
            entry = name
    if entry is None:  # fall back: computation that is not called anywhere
        called = set()
        for text in comps.values():
            called.update(m.group(2) for m in _WHILE_RE.finditer(text))
            called.update(m.group(1) for m in _WHILE_RE.finditer(text))
        candidates = [n for n in comps if n not in called]
        entry = candidates[-1] if candidates else next(iter(comps))

    memo: Dict[str, Dict[str, float]] = {}

    def account(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {}          # cycle guard
        text = comps.get(name, "")
        out: Dict[str, float] = defaultdict(float)
        for m in _OP_RE.finditer(text):
            shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
            if suffix == "-done":
                continue
            out[kind] += _shape_bytes(shape_str)
            out[kind + "_count"] += 1
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            sub = account(body)
            for k, v in sub.items():
                out[k] += trips * v if not k.endswith("_count") else v
        memo[name] = dict(out)
        return memo[name]

    acc = account(entry)
    result: Dict[str, Dict[str, float]] = {}
    for k, v in acc.items():
        if k.endswith("_count"):
            continue
        result[k] = {"bytes": v, "count": acc.get(k + "_count", 0)}
    return result


def total_collective_bytes_loop_aware(hlo_text: str) -> float:
    return sum(v["bytes"]
               for v in collective_bytes_loop_aware(hlo_text).values())
