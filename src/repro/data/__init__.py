from repro.data.pipeline import ShardedLoader, take  # noqa: F401
from repro.data import synthetic  # noqa: F401
