"""Data pipeline: deterministic sharded batching with host-side prefetch.

On a real multi-host TPU job each host feeds its local shard of the global
batch; ``ShardedLoader`` reproduces those semantics (host_id/host_count
slicing of a deterministic global stream) so the trainer code is identical
on 1 host and N hosts.  Prefetch runs generation for step k+1 while step k
is executing (JAX dispatch is async, so overlapping falls out naturally).
"""
from __future__ import annotations

import threading
import queue as queue_mod
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    """Wraps ``make_batch(key, batch_size) -> dict`` into a sharded stream."""

    def __init__(self, make_batch: Callable, global_batch: int, *,
                 seed: int = 0, host_id: int = 0, host_count: int = 1,
                 prefetch: int = 2) -> None:
        assert global_batch % host_count == 0
        self.make_batch = make_batch
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.host_id = host_id
        self.host_count = host_count
        self.seed = seed
        self.prefetch = prefetch

    def _gen(self, step: int) -> Dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        global_batch = self.make_batch(key, self.global_batch)
        lo = self.host_id * self.local_batch
        hi = lo + self.local_batch
        return jax.tree.map(lambda x: x[lo:hi], global_batch)

    def __iter__(self) -> Iterator[Dict]:
        if self.prefetch <= 0:
            step = 0
            while True:
                yield self._gen(step)
                step += 1
            return

        q: queue_mod.Queue = queue_mod.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = 0
            while not stop.is_set():
                try:
                    q.put(self._gen(step), timeout=0.5)
                    step += 1
                except queue_mod.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def take(loader: ShardedLoader, n: int):
    it = iter(loader)
    return [next(it) for _ in range(n)]
