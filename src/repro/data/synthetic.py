"""Synthetic experiment-data generators — the paper's "S"(imulate) op.

* Bragg-peak patches (HEDM): pseudo-Voigt-shaped peaks on noisy background;
  the ground-truth centers play the role of physics, and the conventional
  "A" operation (analysis/pseudo_voigt.py) recovers them to produce training
  labels for BraggNN — exactly the paper's pipeline.
* CookieBox eToF histograms: 16 channels of photo-electron energy histograms
  whose underlying smooth pdf is CookieNetAE's regression target.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import pv_profile


# ---------------------------------------------------------------------------
def bragg_patches(key, n: int, patch: int = 11, *, noise: float = 0.01,
                  amp_range=(0.5, 2.0), gamma_range=(0.8, 1.8),
                  jitter: float = 1.5) -> Dict[str, jax.Array]:
    """Returns {"patches": (n, p, p, 1), "centers": (n, 2) in [0,1]}.

    Peak centers are uniformly jittered around the patch center (peaks are
    pre-localized to +-jitter px by the detector's coarse maximum search).
    """
    kc, ka, kg, kn = jax.random.split(key, 4)
    mid = (patch - 1) / 2.0
    centers = mid + jax.random.uniform(kc, (n, 2), minval=-jitter,
                                       maxval=jitter)
    amps = jax.random.uniform(ka, (n,), minval=amp_range[0],
                              maxval=amp_range[1])
    gammas = jax.random.uniform(kg, (n,), minval=gamma_range[0],
                                maxval=gamma_range[1])
    yy, xx = jnp.mgrid[0:patch, 0:patch]

    def one(c, a, g):
        return a * pv_profile(yy - c[0], g) * pv_profile(xx - c[1], g)

    img = jax.vmap(one)(centers, amps, gammas)
    img = img + noise * jax.random.normal(kn, img.shape)
    img = jnp.clip(img, 0.0, None)
    # normalize each patch to [0, 1] like the BraggNN reference
    mx = img.max(axis=(1, 2), keepdims=True)
    img = img / jnp.maximum(mx, 1e-9)
    return {
        "patches": img[..., None].astype(jnp.float32),
        "centers": (centers / (patch - 1)).astype(jnp.float32),
    }


# ---------------------------------------------------------------------------
def cookiebox_shots(key, n: int, channels: int = 16, bins: int = 128, *,
                    counts: int = 200) -> Dict[str, jax.Array]:
    """Returns {"images": (n, ch, bins, 1) histograms, "targets": same, pdf}.

    Physics stand-in: each shot has 1-3 spectral lines whose intensity varies
    sinusoidally with detector angle (circular polarization signature); the
    empirical histogram is a low-count Poisson draw from the pdf — the hard
    regime the paper mentions ("number of detected electrons is low").
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n_lines = 3
    line_pos = jax.random.uniform(k1, (n, n_lines), minval=10.0,
                                  maxval=bins - 10.0)
    line_w = jax.random.uniform(k2, (n, n_lines), minval=2.0, maxval=6.0)
    phase = jax.random.uniform(k3, (n, n_lines), minval=0.0,
                               maxval=2 * jnp.pi)
    strength = jax.random.uniform(k4, (n, n_lines), minval=0.2, maxval=1.0)

    theta = jnp.arange(channels) * (2 * jnp.pi / channels)
    x = jnp.arange(bins, dtype=jnp.float32)

    # pdf[n, ch, bins] = sum_l strength * angular * spectral-line
    ang = 0.5 * (1 + jnp.cos(theta[None, :, None] - phase[:, None, :]))
    gaus = jnp.exp(-(x[None, None, :] - line_pos[:, :, None]) ** 2
                   / (2 * line_w[:, :, None] ** 2))      # (n, l, bins)
    pdf = jnp.einsum("nl,ncl,nlb->ncb", strength, ang, gaus)
    pdf = pdf / jnp.maximum(pdf.sum(axis=-1, keepdims=True), 1e-9)

    counts_map = jax.random.poisson(k5, counts * pdf)
    hist = counts_map.astype(jnp.float32)
    hist = hist / jnp.maximum(hist.sum(axis=-1, keepdims=True), 1.0)
    return {
        "images": hist[..., None],
        "targets": pdf[..., None].astype(jnp.float32),
    }


# ---------------------------------------------------------------------------
def lm_token_batch(key, batch: int, seq: int, vocab: int
                   ) -> Dict[str, jax.Array]:
    """Synthetic next-token LM batch with a learnable bigram structure."""
    k1, k2 = jax.random.split(key)
    # tokens follow x_{t+1} = (a * x_t + b + noise) mod vocab
    a = 31
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    steps = jnp.arange(seq)
    noise = jax.random.randint(k2, (batch, seq), 0, 3)
    tokens = (start * (a ** 0) + 0)  # placeholder, build iteratively below

    def step(x, n):
        nxt = (a * x + 7 + n) % vocab
        return nxt, nxt

    _, seq_toks = jax.lax.scan(step, start[:, 0], jnp.moveaxis(noise, 1, 0))
    tokens = jnp.moveaxis(seq_toks, 0, 1)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    labels = labels.at[:, -1].set(-1)   # no target for the last position
    return {"tokens": tokens, "labels": labels}
