from repro.analysis.pseudo_voigt import analyze_patches, label_for_braggnn  # noqa: F401
