"""The conventional "A"(nalyze) operation: pseudo-Voigt Bragg-peak fitting.

This is the compute step the paper's ML surrogate replaces (BraggNN predicts
what this produces, ~200x faster).  Two execution paths:
  * ``analyze_patches(..., use_kernel=True)``  — Pallas TPU kernel
    (kernels/pseudo_voigt.py; interpret mode on CPU);
  * ``use_kernel=False`` — pure-jnp XLA path (kernels/ref.py).

Output: per-patch peak centers (y0, x0) in pixels + fit diagnostics.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref


def analyze_patches(patches: jax.Array, *, n_iter: int = 5,
                    use_kernel: bool = True) -> Dict[str, jax.Array]:
    """patches: (N, ph, pw) or (N, ph, pw, 1) -> dict of fit results."""
    if patches.ndim == 4:
        patches = patches[..., 0]
    if use_kernel:
        fits = kernel_ops.pseudo_voigt_fit(patches, n_iter=n_iter)
    else:
        fits = kernel_ref.pseudo_voigt_reference(patches, n_iter=n_iter)
    return {
        "centers_px": fits[:, :2],            # (y0, x0)
        "gammas": fits[:, 2:4],
        "amplitudes": fits[:, 4:6],
    }


def label_for_braggnn(patches: jax.Array, *, use_kernel: bool = True
                      ) -> jax.Array:
    """Produce BraggNN training targets (centers normalized to [0,1])."""
    if patches.ndim == 4:
        p2 = patches[..., 0]
    else:
        p2 = patches
    res = analyze_patches(p2, use_kernel=use_kernel)
    n = p2.shape[1] - 1
    return res["centers_px"] / n
