"""Pallas TPU mLSTM chunkwise-parallel kernel (xLSTM's matrix-memory cell).

Same TPU-native schedule as ssm_scan: grid = (batch, heads, num_chunks) with
the chunk axis minor/sequential, so the stabilized recurrent state
(C: hd x hd matrix memory, n: hd normalizer, m: scalar stabilizer) lives in
VMEM scratch and is carried across chunks.  Per chunk, everything is
(Q x hd)/(Q x Q) matmul work on the MXU plus VPU gate math:

    b      = cumsum(log_f)                       intra-chunk gate decay
    W[t,j] = b_t - b_j + log_i_j   (j <= t)      log intra weights
    m_pos  = max(rowmax(W), b + m_prev)          per-position stabilizer
    S      = (q k^T / sqrt(hd)) * exp(W - m_pos)
    h      = [S v + e^(b+m_prev-m_pos) (q C_prev)] / max(|den|, e^-m_pos)
    state  = e^(bQ+m_prev-m_new) C_prev + (k e^(bQ-b+log_i-m_new))^T v

Oracle: models/xlstm.py::mlstm_chunkwise (same math, stacked-batch jnp) —
itself cross-validated against the sequential decode recurrence in
tests/test_decode_consistency.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  c_scr, n_scr, m_scr, *, chunk: int, hd: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    scale = 1.0 / (hd ** 0.5)
    q = q_ref[0, 0].astype(jnp.float32) * scale    # (Q, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)          # (Q,)
    lf = lf_ref[0, 0].astype(jnp.float32)

    b = jnp.cumsum(lf)                             # (Q,)
    bQ = b[-1]
    m_prev = m_scr[0, 0]

    # intra-chunk log weights
    wmat = b[:, None] - b[None, :] + li[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    wmat = jnp.where(jj <= ii, wmat, NEG)
    m_pos = jnp.maximum(wmat.max(axis=1), b + m_prev)   # (Q,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    S = s * jnp.exp(wmat - m_pos[:, None])

    inter_w = jnp.exp(b + m_prev - m_pos)          # (Q,)
    num = jax.lax.dot_general(S, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    num = num + inter_w[:, None] * jax.lax.dot_general(
        q, c_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    den = S.sum(axis=1) + inter_w * jax.lax.dot_general(
        q, n_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_pos))
    h_ref[0, 0] = (num / denom[:, None]).astype(h_ref.dtype)

    # state update
    upd_w = bQ - b + li                            # (Q,)
    m_new = jnp.maximum(bQ + m_prev, upd_w.max())
    k_scaled = k * jnp.exp(upd_w - m_new)[:, None]
    decay = jnp.exp(bQ + m_prev - m_new)
    c_scr[...] = decay * c_scr[...] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_scr[...] = decay * n_scr[...] + k_scaled.sum(axis=0)[:, None]
    m_scr[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_i: jax.Array,
               log_f: jax.Array, *, chunk: int = 128,
               interpret: bool = False) -> jax.Array:
    """q/k/v: (B, H, L, hd); log_i/log_f: (B, H, L) fp32.
    Returns h (B, H, L, hd)."""
    B, H, L, hd = q.shape
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    return pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk, hd=hd),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),   # C matrix memory
            pltpu.VMEM((hd, 1), jnp.float32),    # n normalizer
            pltpu.VMEM((1, 1), jnp.float32),     # m stabilizer
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
