"""Public jit'd wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (Pallas
interprets the kernel body in Python); on a real TPU the same calls compile
to Mosaic.  ``KERNEL_INTERPRET`` auto-detects the backend; pass
``interpret=`` explicitly to override.

Each wrapper handles padding/layout so callers can use model-native shapes.

Mesh-sharded serving note: the paged-attention wrappers take
``use_kernel`` so the engine can pin the jnp reference path on >1-device
meshes — a Pallas call is opaque to GSPMD and cannot be partitioned,
while the reference path's gathers/einsums partition along the
kv-head-sharded pool with replicated (T,)-stream metadata (see
``docs/ARCHITECTURE.md`` §7).  On a 1-device mesh the kernel dispatch is
unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pseudo_voigt as _pv
from repro.kernels import ssm_scan as _ssd


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
def flash_attention_bshd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Model-layout wrapper: q (B,S,H,D), k/v (B,S,Hkv,D) -> (B,S,H,D).

    Pads S up to a block multiple (masked out via the causal mask since
    padded queries only ever see padded keys at the tail).
    """
    if interpret is None:
        interpret = default_interpret()
    B, S, H, D = q.shape
    bq = min(block_q, max(16, S))
    bkv = min(block_kv, max(16, S))
    pad = (-S) % max(bq, bkv)
    if pad:
        zq = jnp.zeros((B, pad, H, D), q.dtype)
        zk = jnp.zeros((B, pad, k.shape[2], D), k.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=bq, block_kv=bkv, interpret=interpret)
    out = jnp.swapaxes(out, 1, 2)
    return out[:, :S] if pad else out


# ---------------------------------------------------------------------------
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, ctx_lens: jax.Array, *,
                    window: int = 0,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Decode-time paged attention read: q (B, 1, H, D) or (B, H, D)
    against KV pools (num_blocks, bs, Hkv, D) via per-lane block tables.
    With int8 pools, ``k_scale``/``v_scale`` carry the per-(block, slot,
    kv-head) dequantization scales ((num_blocks, bs, Hkv) float32).

    Backend dispatch: on TPU the Pallas kernel gathers blocks through its
    scalar-prefetched index maps; on CPU the pure-JAX reference (an XLA
    gather + masked softmax) is the production path — interpret-mode Pallas
    is far too slow for a per-token serving loop.
    """
    from repro.kernels import paged_attention as _pa
    from repro.kernels import ref as _ref
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        B, H, D = q.shape
        Hkv = k_pool.shape[2]
        qg = q.reshape(B, Hkv, H // Hkv, D)
        out = _pa.paged_attention(qg, k_pool, v_pool, block_tables,
                                  ctx_lens, window=window,
                                  interpret=interpret,
                                  k_scale=k_scale, v_scale=v_scale)
        out = out.reshape(B, H, D)
    else:
        out = _ref.paged_attention_reference(q, k_pool, v_pool,
                                             block_tables, ctx_lens,
                                             window=window,
                                             k_scale=k_scale,
                                             v_scale=v_scale)
    return out[:, None] if squeeze else out


def paged_attention_chunk(q: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, block_tables: jax.Array,
                          q_starts: jax.Array, q_lens: jax.Array, *,
                          window: int = 0,
                          use_kernel: Optional[bool] = None,
                          interpret: Optional[bool] = None,
                          k_scale: Optional[jax.Array] = None,
                          v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Chunked paged attention read: q (B, C, H, D) — C query tokens per
    lane starting at absolute position ``q_starts[b]``, of which
    ``q_lens[b]`` are real (padded rows compute garbage the caller
    ignores) — against KV pools (num_blocks, bs, Hkv, D) via per-lane
    block tables.  Causal masking inside the chunk; the unified
    prefill+decode serving path (C = 1 is plain decode).

    Backend dispatch mirrors :func:`paged_attention`: Pallas kernel on TPU,
    pure-JAX reference (XLA gather + masked softmax) on CPU.
    """
    from repro.kernels import paged_attention as _pa
    from repro.kernels import ref as _ref
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        B, C, H, D = q.shape
        Hkv = k_pool.shape[2]
        q5 = jnp.transpose(q.reshape(B, C, Hkv, H // Hkv, D),
                           (0, 2, 1, 3, 4))
        out = _pa.paged_attention_chunk(q5, k_pool, v_pool, block_tables,
                                        q_starts, q_starts + q_lens,
                                        window=window, interpret=interpret,
                                        k_scale=k_scale, v_scale=v_scale)
        return jnp.transpose(out, (0, 2, 1, 3, 4)).reshape(B, C, H, D)
    return _ref.paged_attention_chunk_reference(q, k_pool, v_pool,
                                                block_tables, q_starts,
                                                window=window,
                                                k_scale=k_scale,
                                                v_scale=v_scale)


def paged_attention_ragged(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, token_tables: jax.Array,
                           token_pos: jax.Array, *, window: int = 0,
                           use_kernel: Optional[bool] = None,
                           interpret: Optional[bool] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Flat-token-stream paged attention read: q (T, H, D) — one 1-D batch
    of T tokens freely mixing prefill chunks and decodes from many lanes —
    against KV pools (num_blocks, bs, Hkv, D).  ``token_tables`` (T,
    max_blocks) carries each token's lane's block-table row and
    ``token_pos`` (T,) its absolute position (the causal bound).  No
    rectangular (lanes, chunk_width) padding exists anywhere: work is
    proportional to T = sum of real scheduled tokens.

    Backend dispatch mirrors :func:`paged_attention`: Pallas kernel on TPU,
    pure-JAX reference (XLA gather + masked softmax) on CPU.
    """
    from repro.kernels import paged_attention as _pa
    from repro.kernels import ref as _ref
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        T, H, D = q.shape
        Hkv = k_pool.shape[2]
        qg = q.reshape(T, Hkv, H // Hkv, D)
        out = _pa.paged_attention_ragged(qg, k_pool, v_pool, token_tables,
                                         token_pos, window=window,
                                         interpret=interpret,
                                         k_scale=k_scale, v_scale=v_scale)
        return out.reshape(T, H, D)
    return _ref.paged_attention_ragged_reference(q, k_pool, v_pool,
                                                 token_tables, token_pos,
                                                 window=window,
                                                 k_scale=k_scale,
                                                 v_scale=v_scale)


def paged_attention_ragged_tiled(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, block_tables: jax.Array,
                                 tile_meta: jax.Array, row_tile: jax.Array,
                                 *, tile: int, window: int = 0,
                                 use_kernel: Optional[bool] = None,
                                 interpret: Optional[bool] = None,
                                 k_scale: Optional[jax.Array] = None,
                                 v_scale: Optional[jax.Array] = None
                                 ) -> jax.Array:
    """Segment-tiled flat-stream paged attention read: the same q (T, H, D)
    stream as :func:`paged_attention_ragged`, attended through the tile
    metadata built by ``serving.batch.build_tile_map`` — ``block_tables``
    (n_lanes, max_blocks) per-lane rows, ``tile_meta`` (5, n_tiles) int32
    (window / row span / position / lane per tile; rows = ``ref.TILE_*``),
    ``row_tile`` (T,) each flat row's owning tile.  Every lane's KV blocks
    are read once per q-tile (kernel) / once per lane span (reference)
    instead of once per token.

    Backend dispatch mirrors :func:`paged_attention`: Pallas kernel on TPU,
    pure-JAX tiled reference (per-lane span gather + masked softmax) on
    CPU.
    """
    from repro.kernels import paged_attention as _pa
    from repro.kernels import ref as _ref
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        T, H, D = q.shape
        Hkv = k_pool.shape[2]
        qg = q.reshape(T, Hkv, H // Hkv, D)
        out = _pa.paged_attention_ragged_tiled(qg, k_pool, v_pool,
                                               block_tables, tile_meta,
                                               row_tile, tile=tile,
                                               window=window,
                                               interpret=interpret,
                                               k_scale=k_scale,
                                               v_scale=v_scale)
        return out.reshape(T, H, D)
    return _ref.paged_attention_ragged_tiled_reference(
        q, k_pool, v_pool, block_tables, tile_meta, row_tile, tile=tile,
        window=window, k_scale=k_scale, v_scale=v_scale)


# ---------------------------------------------------------------------------
def ssd_scan_heads(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                   Cm: jax.Array, *, chunk: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Model-layout wrapper matching models/ssm.py::ssd_chunked.

    x: (B,L,H,P); dt: (B,L,H) (softplus'd); A: (H,) negative;
    Bm/Cm: (B,L,G,N).  Returns y (B,L,H,P).
    """
    if interpret is None:
        interpret = default_interpret()
    B, L, H, P = x.shape
    xdt = (x * dt[..., None].astype(x.dtype))
    xdt = jnp.transpose(xdt, (0, 2, 1, 3))              # (B,H,L,P)
    dA = jnp.transpose(dt * A[None, None, :], (0, 2, 1))  # (B,H,L)
    Bm_t = jnp.transpose(Bm, (0, 2, 1, 3))              # (B,G,L,N)
    Cm_t = jnp.transpose(Cm, (0, 2, 1, 3))
    c = min(chunk, L)
    y = _ssd.ssd_scan(xdt, dA.astype(jnp.float32), Bm_t, Cm_t,
                      chunk=c, interpret=interpret)
    return jnp.transpose(y, (0, 2, 1, 3))               # (B,L,H,P)


# ---------------------------------------------------------------------------
def pseudo_voigt_fit(patches: jax.Array, *, n_iter: int = 5,
                     block: int = 256,
                     interpret: Optional[bool] = None) -> jax.Array:
    """patches (Np, ph, pw) -> (Np, 6); pads Np to a block multiple."""
    if interpret is None:
        interpret = default_interpret()
    Np = patches.shape[0]
    blk = min(block, max(8, Np))
    pad = (-Np) % blk
    if pad:
        patches = jnp.concatenate(
            [patches, jnp.zeros((pad,) + patches.shape[1:], patches.dtype)])
    out = _pv.pseudo_voigt_fit(patches, n_iter=n_iter, block=blk,
                               interpret=interpret)
    return out[:Np]


# ---------------------------------------------------------------------------
def mlstm_scan_heads(q: jax.Array, k: jax.Array, v: jax.Array,
                     log_i: jax.Array, log_f: jax.Array, *,
                     chunk: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Model-layout wrapper matching models/xlstm.py::mlstm_chunkwise.

    q/k/v: (B, L, H, hd); log_i/log_f: (B, L, H).  Returns (B, L, H, hd).
    """
    from repro.kernels import mlstm_scan as _ml
    if interpret is None:
        interpret = default_interpret()
    B, L, H, hd = q.shape
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    li = jnp.transpose(log_i, (0, 2, 1)).astype(jnp.float32)
    lf = jnp.transpose(log_f, (0, 2, 1)).astype(jnp.float32)
    h = _ml.mlstm_scan(qt, kt, vt, li, lf, chunk=min(chunk, L),
                       interpret=interpret)
    return jnp.transpose(h, (0, 2, 1, 3))
