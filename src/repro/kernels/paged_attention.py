"""Pallas TPU paged-attention kernels — rectangular (per-lane chunk) and
ragged (flat token stream).

A chunk of C query tokens per lane (C = 1 is plain decode) attends over its
KV sequence scattered across fixed-size physical blocks of a shared pool;
the ragged variant (:func:`paged_attention_ragged`) drops the per-lane
rectangle entirely and serves one flat 1-D stream of mixed prefill/decode
tokens with per-token lane metadata.
The gather is expressed in the BlockSpec index maps: the per-lane block
table is a *scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``), so
the j-th kv DMA of lane b fetches physical block ``block_tables[b, j]``
directly from the pool — no materialized (B, S, ...) gather ever exists in
HBM.

Schedule:
  * grid = (batch_lane, kv_head, logical_block); the trailing axis runs
    sequentially on a TPU core, carrying the online-softmax state (m, l,
    acc) for one lane/head across that lane's blocks in VMEM scratch;
  * blocks at or past the lane's context length are skipped with
    ``pl.when`` (their DMA still targets a legal pool slot — idle table
    entries point at the reserved null block 0);
  * GQA + chunking: all C chunk tokens of all G = H/Hkv query heads of a
    kv head ride in one (C*G, D) tile; row r of the tile is chunk token
    ``r // G``, so its absolute position is ``q_starts[b] + r // G`` and
    the causal mask *inside* the chunk falls out of one iota compare;
  * padded chunk rows (past a lane's real q_len) compute finite garbage
    the caller ignores — their kv reads stay inside the lane's legal
    blocks, so they can never fault.

Validated in interpret mode against ``ref.paged_attention_*reference``
(tests/test_kernels_paged_attention.py); the pure-JAX reference is also the
production CPU path (kernels/ops.py dispatches on backend), and the
serving path on >1-device meshes — a Pallas call is opaque to GSPMD, so
mesh-sharded engines pin ``use_kernel=False`` until these kernels grow a
shard_map wrapper (each shard would run the identical grid over its
kv-head slice of the pool; see ``docs/ARCHITECTURE.md`` §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import (TILE_HI, TILE_LANE, TILE_LO, TILE_POS0,
                               TILE_WINDOW)

NEG_INF = -1e30


def _paged_attn_kernel(tables_ref, ctx_ref, start_ref, q_ref, k_ref, v_ref,
                       *rest, block_size: int, window: int, scale: float,
                       group: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)          # logical block index within lane b
    nblk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = ctx_ref[b]              # valid tokens in lane b after this chunk
    start = start_ref[b]          # absolute position of chunk row 0

    @pl.when(j * block_size < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (C*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
        v = v_ref[0, :, 0]                               # (bs, D)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (C*G, bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
        qpos = start + jax.lax.broadcasted_iota(jnp.int32,
                                                s.shape, 0) // group
        mask = kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (C*G, D)
        m_scr[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _paged_attention_rows(q_rows: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, block_tables: jax.Array,
                          ctx_lens: jax.Array, q_starts: jax.Array, *,
                          group: int, window: int, interpret: bool,
                          k_scale=None, v_scale=None) -> jax.Array:
    """Shared launcher: q_rows (B, Hkv, R, D) with R = C * group rows."""
    B, Hkv, R, D = q_rows.shape
    num_blocks, bs, Hkv_p, _ = k_pool.shape
    assert Hkv_p == Hkv, (Hkv_p, Hkv)
    max_blocks = block_tables.shape[1]
    scale = 1.0 / (D ** 0.5)
    quantized = k_scale is not None

    kernel = functools.partial(_paged_attn_kernel, block_size=bs,
                               window=window, scale=scale, group=group,
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, R, D),
                     lambda b, h, j, tables, ctx, starts: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda b, h, j, tables, ctx, starts:
                     (tables[b, j], 0, h, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda b, h, j, tables, ctx, starts:
                     (tables[b, j], 0, h, 0)),
    ]
    operands = [q_rows, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, j, tables, ctx, starts:
                         (tables[b, j], 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, j, tables, ctx, starts:
                         (tables[b, j], 0, h)),
        ]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, max_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, R, D),
                               lambda b, h, j, tables, ctx, starts:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),   # m
            pltpu.VMEM((R, 1), jnp.float32),   # l
            pltpu.VMEM((R, D), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q_rows.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      q_starts.astype(jnp.int32), *operands)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, ctx_lens: jax.Array, *,
                    window: int = 0, interpret: bool = False,
                    k_scale: jax.Array = None,
                    v_scale: jax.Array = None) -> jax.Array:
    """Decode (q_len = 1): q (B, Hkv, G, D) at position ``ctx_lens - 1``;
    pools: (num_blocks, bs, Hkv, D); block_tables: (B, max_blocks) int32
    physical ids (null block = 0 for unallocated logical blocks);
    ctx_lens: (B,) int32.  With int8 pools, ``k_scale``/``v_scale``
    ((num_blocks, bs, Hkv) float32) ride the same table-indexed DMAs and
    dequantize each block tile in VMEM.  Returns (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    out = _paged_attention_rows(q, k_pool, v_pool, block_tables, ctx_lens,
                                ctx_lens - 1, group=G, window=window,
                                interpret=interpret, k_scale=k_scale,
                                v_scale=v_scale)
    return out


def _ragged_attn_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                        block_size: int, window: int, scale: float,
                        quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    t = pl.program_id(0)          # flat token index
    j = pl.program_id(2)          # logical block index within the token's lane
    nblk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    tpos = pos_ref[t]             # token t's absolute position in its lane

    @pl.when(j * block_size <= tpos)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
        v = v_ref[0, :, 0]                               # (bs, D)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
        mask = kpos <= tpos
        if window:
            mask &= (tpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, D)
        m_scr[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_ragged(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, token_tables: jax.Array,
                           token_pos: jax.Array, *, window: int = 0,
                           interpret: bool = False,
                           k_scale: jax.Array = None,
                           v_scale: jax.Array = None) -> jax.Array:
    """Flat-token-stream paged attention: q (T, Hkv, G, D) — one mixed
    batch of T tokens from many lanes with NO per-lane rectangle.  Token t
    attends causally over its own lane's blocks (``token_tables[t]``, the
    lane's block-table row scalar-prefetched per token) up to its absolute
    position ``token_pos[t]``.  The grid is (token, kv_head, block): the
    kernel does work proportional to the real scheduled tokens, and each
    token's block sweep stops at its *own* position (``j*bs <= pos``) —
    strictly less kv traffic than the rectangular kernel, which sweeps
    every row to the lane's full context.  Padding tokens (null tables,
    position 0) stay inside the reserved null block and yield garbage the
    caller ignores.  Returns (T, Hkv, G, D)."""
    T, Hkv, G, D = q.shape
    num_blocks, bs, Hkv_p, _ = k_pool.shape
    assert Hkv_p == Hkv, (Hkv_p, Hkv)
    max_blocks = token_tables.shape[1]
    scale = 1.0 / (D ** 0.5)

    quantized = k_scale is not None
    kernel = functools.partial(_ragged_attn_kernel, block_size=bs,
                               window=window, scale=scale,
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, G, D),
                     lambda t, h, j, tables, pos: (t, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda t, h, j, tables, pos:
                     (tables[t, j], 0, h, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda t, h, j, tables, pos:
                     (tables[t, j], 0, h, 0)),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1),
                         lambda t, h, j, tables, pos:
                         (tables[t, j], 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda t, h, j, tables, pos:
                         (tables[t, j], 0, h)),
        ]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, Hkv, max_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda t, h, j, tables, pos: (t, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, D), jnp.float32),   # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(token_tables.astype(jnp.int32), token_pos.astype(jnp.int32),
      *operands)


def _tiled_ragged_attn_kernel(meta_ref, tables_ref, q_ref, k_ref, v_ref,
                              *rest, block_size: int, tile: int,
                              window: int, scale: float, group: int,
                              quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    t = pl.program_id(0)          # tile = one (q-window, segment) pair
    j = pl.program_id(2)          # logical block index within the tile's lane
    nblk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo = meta_ref[TILE_LO, t]     # the tile's flat-row span [lo, hi)
    hi = meta_ref[TILE_HI, t]
    pos0 = meta_ref[TILE_POS0, t]          # sequence position of row lo
    row0 = meta_ref[TILE_WINDOW, t] * tile  # flat row of the window's row 0
    maxpos = pos0 + hi - 1 - lo            # deepest causal bound in the tile

    @pl.when((lo < hi) & (j * block_size <= maxpos))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (tile*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
        v = v_ref[0, :, 0]                               # (bs, D)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (tile*G, bs)
        tok = row0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        qpos = pos0 + tok - lo
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
        # in-tile causal mask + window-rows outside this tile's segment
        mask = (tok >= lo) & (tok < hi) & (kpos <= qpos)
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (tile*G, D)
        m_scr[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "window", "interpret"))
def paged_attention_ragged_tiled(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, block_tables: jax.Array,
                                 tile_meta: jax.Array, row_tile: jax.Array,
                                 *, tile: int, window: int = 0,
                                 interpret: bool = False,
                                 k_scale: jax.Array = None,
                                 v_scale: jax.Array = None) -> jax.Array:
    """Segment-tiled flat-stream paged attention: q (T, Hkv, G, D), the
    same mixed 1-D token batch as :func:`paged_attention_ragged`, but tiled
    so each lane's KV blocks are DMA'd once per *q-tile* instead of once
    per token.

    The stream is covered by fixed ``tile``-row q windows; ``tile_meta``
    (5, n_tiles) int32 (rows = ``ref.TILE_*``; built by
    ``serving.batch.build_tile_map``) names, per tile, the window it loads,
    its flat-row span ``[lo, hi)`` inside one segment, the sequence
    position of row ``lo``, and the owning lane whose ``block_tables`` row
    the kv index maps sweep.  The grid is (tile, kv_head, block): one
    (tile*G, D) query slab rides per tile — ``tile`` times fewer kv DMAs
    than the per-token grid and a ``tile``-times taller MXU tile at small
    GQA group sizes.  Straddled windows are split into one tile per
    segment; each tile masks the window rows outside its own span, and the
    per-row outputs are gathered back through ``row_tile`` (T,).  Inert
    capacity-padding tiles (lo == hi) skip all compute; stream-padding
    rows yield finite garbage the caller ignores.  Returns (T, Hkv, G, D).
    """
    T, Hkv, G, D = q.shape
    num_blocks, bs, Hkv_p, _ = k_pool.shape
    assert Hkv_p == Hkv, (Hkv_p, Hkv)
    max_blocks = block_tables.shape[1]
    n_tiles = tile_meta.shape[1]
    scale = 1.0 / (D ** 0.5)

    n_windows = -(-T // tile)
    pad = n_windows * tile - T
    qw = jnp.pad(q, ((0, pad), (0, 0), (0, 0), (0, 0)))
    qw = qw.reshape(n_windows, tile, Hkv, G, D).transpose(0, 2, 1, 3, 4)
    qw = qw.reshape(n_windows, Hkv, tile * G, D)

    quantized = k_scale is not None
    kernel = functools.partial(_tiled_ragged_attn_kernel, block_size=bs,
                               tile=tile, window=window, scale=scale,
                               group=G, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, tile * G, D),
                     lambda t, h, j, meta, tables:
                     (meta[TILE_WINDOW, t], h, 0, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda t, h, j, meta, tables:
                     (tables[meta[TILE_LANE, t], j], 0, h, 0)),
        pl.BlockSpec((1, bs, 1, D),
                     lambda t, h, j, meta, tables:
                     (tables[meta[TILE_LANE, t], j], 0, h, 0)),
    ]
    operands = [qw, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1),
                         lambda t, h, j, meta, tables:
                         (tables[meta[TILE_LANE, t], j], 0, h)),
            pl.BlockSpec((1, bs, 1),
                         lambda t, h, j, meta, tables:
                         (tables[meta[TILE_LANE, t], j], 0, h)),
        ]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, Hkv, max_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, tile * G, D),
                               lambda t, h, j, meta, tables: (t, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tile * G, 1), jnp.float32),   # m
            pltpu.VMEM((tile * G, 1), jnp.float32),   # l
            pltpu.VMEM((tile * G, D), jnp.float32),   # acc
        ],
    )
    out_tiles = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, Hkv, tile * G, D), q.dtype),
        interpret=interpret,
    )(tile_meta.astype(jnp.int32), block_tables.astype(jnp.int32),
      *operands)

    # gather every real row's (Hkv, G, D) slab back from its owning tile
    t_idx = row_tile[:T].astype(jnp.int32)
    off = jnp.clip(jnp.arange(T) - tile_meta[TILE_WINDOW, t_idx] * tile,
                   0, tile - 1)
    rows = out_tiles.reshape(n_tiles, Hkv, tile, G, D)
    return rows[t_idx, :, off]                        # (T, Hkv, G, D)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_chunk(q: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, block_tables: jax.Array,
                          q_starts: jax.Array, ctx_lens: jax.Array, *,
                          window: int = 0, interpret: bool = False,
                          k_scale: jax.Array = None,
                          v_scale: jax.Array = None) -> jax.Array:
    """Chunked prefill/decode: q (B, Hkv, C, G, D) — C query tokens per
    lane, token c at absolute position ``q_starts[b] + c``, causally masked
    inside the chunk.  ``ctx_lens`` (B,) is each lane's total valid kv
    length after the chunk (bounds the block sweep; padded chunk rows past
    it yield garbage the caller ignores).  Returns (B, Hkv, C, G, D)."""
    B, Hkv, C, G, D = q.shape
    q_rows = q.reshape(B, Hkv, C * G, D)
    out = _paged_attention_rows(q_rows, k_pool, v_pool, block_tables,
                                ctx_lens, q_starts, group=G, window=window,
                                interpret=interpret, k_scale=k_scale,
                                v_scale=v_scale)
    return out.reshape(B, Hkv, C, G, D)
