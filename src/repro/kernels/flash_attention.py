"""Pallas TPU flash-attention (forward) with causal + sliding-window masks.

TPU-native schedule (not a CUDA port):
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the trailing
    (minor) grid axis executes sequentially on a TPU core, so the online-
    softmax running state (m, l, acc) lives in VMEM scratch and is carried
    across kv-block steps of one q block;
  * BlockSpecs tile q/k/v into (block_q x head_dim) / (block_kv x head_dim)
    VMEM tiles; block sizes default to 128 to keep the MXU matmuls
    128-aligned;
  * GQA is expressed in the k/v index_map (q-head -> kv-head, no repeat);
  * fully-masked kv blocks (outside the causal band or sliding window) are
    skipped with ``pl.when`` — the band structure, not the full quadratic,
    is what gets executed.

Validated in interpret mode against kernels/ref.py (pure jnp oracle); see
tests/test_kernels_attention.py for the shape/dtype sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_kv: int, seq_len: int,
                 causal: bool, window: int):
    i = pl.program_id(2)          # q block index
    j = pl.program_id(3)          # kv block index
    nkv = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # is this kv block inside the causal/window band of this q block?
    q_lo = i * block_q
    q_hi = q_lo + block_q - 1
    k_lo = j * block_kv
    k_hi = k_lo + block_kv - 1
    in_band = True
    if causal:
        in_band = k_lo <= q_hi
    if window:
        in_band = in_band & (k_hi > q_lo - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bkv, D)
        v = v_ref[0, 0]                                 # (bkv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bkv)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                          # (bq, bkv)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, D)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, S, D).  Returns (B, H, S, D).

    S must be divisible by the block sizes (pad upstream); D is the head
    dim (any size; MXU prefers multiples of 128).
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    nq = S // block_q
    nkv = S // block_kv
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        seq_len=S, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)


# ===========================================================================
# Backward pass (dq, dk, dv) — same banded schedule as the forward.
# ===========================================================================
def _attn_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                         m_scr, l_scr, acc_scr, *, scale, block_q, block_kv,
                         seq_len, causal, window):
    """Forward that also emits the logsumexp rows needed by backward."""
    _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 scale=scale, block_q=block_q, block_kv=block_kv,
                 seq_len=seq_len, causal=causal, window=window)
    j = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(j == nkv - 1)
    def _emit():
        lse = m_scr[...][:, 0] + jnp.log(jnp.maximum(l_scr[...][:, 0],
                                                     1e-30))
        lse_ref[0, 0] = lse.astype(lse_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, block_q, block_kv, causal, window):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = i * block_q
    k_lo = j * block_kv
    in_band = True
    if causal:
        in_band = k_lo <= q_lo + block_q - 1
    if window:
        in_band = in_band & (k_lo + block_kv - 1 > q_lo - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nkv - 1)
    def _finalize():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, block_q, block_kv,
                causal, window, group):
    j = pl.program_id(2)          # kv block
    g = pl.program_id(3)          # head within kv group
    i = pl.program_id(4)          # q block
    nq = pl.num_programs(4)

    @pl.when((g == 0) & (i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_lo = i * block_q
    k_lo = j * block_kv
    in_band = True
    if causal:
        in_band = k_lo <= q_lo + block_q - 1
    if window:
        in_band = in_band & (k_lo + block_kv - 1 > q_lo - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # (bq, bkv)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bkv, D)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bkv, D)

    @pl.when((g == pl.num_programs(3) - 1) & (i == nq - 1))
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def _flash_fwd_lse(q, k, v, *, causal=True, window=0, block_q=128,
                   block_kv=128, interpret=False):
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    nq, nkv = S // block_q, S // block_kv
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(
        _attn_fwd_lse_kernel, scale=scale, block_q=block_q,
        block_kv=block_kv, seq_len=S, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def _flash_bwd(q, k, v, o, lse, do, *, causal=True, window=0, block_q=128,
               block_kv=128, interpret=False):
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    nq, nkv = S // block_q, S // block_kv
    scale = 1.0 / (D ** 0.5)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                  # (B, H, S)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=block_q,
                          block_kv=block_kv, causal=causal, window=window),
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=block_q,
                          block_kv=block_kv, causal=causal, window=window,
                          group=group),
        grid=(B, Hkv, nkv, group, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, kh, j, g, i, G=group: (b, kh * G + g, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, kh, j, g, i, G=group: (b, kh * G + g, i, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kh, j, g, i, G=group: (b, kh * G + g, i)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kh, j, g, i, G=group: (b, kh * G + g, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, kh, j, g, i: (b, kh, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, S, D), k.dtype),
            jax.ShapeDtypeStruct((B, Hkv, S, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, D), jnp.float32),
            pltpu.VMEM((block_kv, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_trainable(q, k, v, causal=True, window=0, block_q=128,
                              block_kv=128, interpret=False):
    """Differentiable flash attention: Pallas forward AND backward."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           interpret=interpret)


def _fa_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    o, lse = _flash_fwd_lse(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_kv=block_kv,
                            interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, block_q, block_kv, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, o, lse, do, causal=causal,
                            window=window, block_q=block_q,
                            block_kv=block_kv, interpret=interpret)
    return dq, dk, dv


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
