"""Pallas TPU pseudo-Voigt Bragg-peak fitting kernel — the paper's "A" op.

The conventional analysis the paper's ML surrogate replaces (pseudo-Voigt
profiling, §4.2) is the per-experiment compute hot-spot: ~2000 core-seconds
per 800K peaks on CPUs.  This kernel batch-fits peak patches on TPU:

  * one grid step processes a block of 256 patches resident in VMEM
    ((256, 11, 11) input tile, padded to lanes by Mosaic);
  * the separable fit runs Gauss-Newton on the row/column marginals with a
    closed-form 3x3 normal-equation solve — pure VPU element-wise math,
    no MXU needed, fully vectorized over the patch block;
  * fixed iteration count (default 5) keeps the schedule static.

Oracle: kernels/ref.py::pseudo_voigt_reference (identical math, plain jnp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _fit_block(marg: jax.Array, n: int, n_iter: int):
    """Vectorized GN fit on (bp, n) marginals; returns (x0, gamma, A)."""
    x = jnp.arange(n, dtype=jnp.float32)
    bg = marg.min(axis=-1, keepdims=True)
    yc = marg - bg
    total = jnp.maximum(yc.sum(axis=-1), 1e-12)
    x0 = (yc * x).sum(axis=-1) / total
    var = (yc * (x - x0[:, None]) ** 2).sum(axis=-1) / total
    gamma = jnp.sqrt(jnp.maximum(var, 0.25))
    A = jnp.maximum(yc.max(axis=-1), 1e-12)

    for _ in range(n_iter):
        u = x - x0[:, None]
        p, dp_dx0, dp_dg = _ref._pv_grads(u, gamma[:, None])
        r = yc - A[:, None] * p
        j0 = p
        j1 = A[:, None] * dp_dx0
        j2 = A[:, None] * dp_dg
        a00 = (j0 * j0).sum(-1); a01 = (j0 * j1).sum(-1); a02 = (j0 * j2).sum(-1)
        a11 = (j1 * j1).sum(-1); a12 = (j1 * j2).sum(-1); a22 = (j2 * j2).sum(-1)
        b0 = (j0 * r).sum(-1); b1 = (j1 * r).sum(-1); b2 = (j2 * r).sum(-1)
        lam = 1e-6 * (a00 + a11 + a22) + 1e-12
        a00 = a00 + lam; a11 = a11 + lam; a22 = a22 + lam
        det = (a00 * (a11 * a22 - a12 * a12)
               - a01 * (a01 * a22 - a12 * a02)
               + a02 * (a01 * a12 - a11 * a02))
        det = jnp.where(jnp.abs(det) < 1e-20, 1e-20, det)
        i00 = a11 * a22 - a12 * a12
        i01 = a02 * a12 - a01 * a22
        i02 = a01 * a12 - a02 * a11
        i11 = a00 * a22 - a02 * a02
        i12 = a02 * a01 - a00 * a12
        i22 = a00 * a11 - a01 * a01
        dA = (i00 * b0 + i01 * b1 + i02 * b2) / det
        dx0 = (i01 * b0 + i11 * b1 + i12 * b2) / det
        dg = (i02 * b0 + i12 * b1 + i22 * b2) / det
        A = jnp.maximum(A + dA, 1e-12)
        x0 = jnp.clip(x0 + dx0, 0.0, n - 1.0)
        gamma = jnp.clip(gamma + dg, 0.3, float(n))
    return x0, gamma, A


def _pv_kernel(patch_ref, out_ref, *, ph: int, pw: int, n_iter: int):
    patches = patch_ref[...].astype(jnp.float32)       # (bp, ph, pw)
    my = patches.sum(axis=2)                            # (bp, ph)
    mx = patches.sum(axis=1)                            # (bp, pw)
    y0, gy, Ay = _fit_block(my, ph, n_iter)
    x0, gx, Ax = _fit_block(mx, pw, n_iter)
    out = jnp.stack([y0, x0, gy, gx, Ay, Ax], axis=-1)  # (bp, 6)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_iter", "block", "interpret"))
def pseudo_voigt_fit(patches: jax.Array, *, n_iter: int = 5,
                     block: int = 256, interpret: bool = False) -> jax.Array:
    """patches (Np, ph, pw) float -> (Np, 6) fits (y0, x0, gy, gx, Ay, Ax).

    Np must be divisible by ``block`` (pad upstream; ops.py handles it).
    """
    Np, ph, pw = patches.shape
    assert Np % block == 0, (Np, block)
    return pl.pallas_call(
        functools.partial(_pv_kernel, ph=ph, pw=pw, n_iter=n_iter),
        grid=(Np // block,),
        in_specs=[pl.BlockSpec((block, ph, pw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block, 6), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 6), jnp.float32),
        interpret=interpret,
    )(patches)
