"""Pure-jnp reference oracles for every Pallas kernel in this package.

Each function is the mathematical specification the kernel must match
(asserted with ``assert_allclose`` over shape/dtype sweeps in tests/).

The paged-attention references double as the *serving* path on CPU and
on multi-device meshes (where a Pallas call cannot be partitioned by
GSPMD): being ordinary gathers/einsums, they shard transparently when
the KV pools arrive split over kv_heads on a mesh's "model" axis with
everything else replicated — no reference function takes a sharding
argument, placement is entirely the caller's contract
(``docs/ARCHITECTURE.md`` §7).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# flash_attention oracle
# ---------------------------------------------------------------------------
def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,Hkv,S,D) -> (B,H,S,D).  Plain masked softmax."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s / (D ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(B, H, S, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged_attention oracle — gather blocks, then plain masked softmax.
# Also the production CPU serving path (ops.paged_attention* dispatch here),
# so its numerics deliberately mirror models/layers.py::decode_attention
# (scores einsum in input dtype then cast, weights back in q.dtype): a paged
# lane and a dense slot lane produce bit-identical logits.
#
# The chunk form is the general one: each lane carries a chunk of C query
# tokens, query c of lane b sits at absolute position q_starts[b] + c and
# attends causally *inside* the chunk (kpos <= qpos).  Single-token decode
# is the C = 1 special case with q_starts = ctx_lens - 1.
#
# int8 pools: when the engine stores quantized blocks, every reference
# takes the per-(block, slot, kv-head) scale pools ((num_blocks, bs, Hkv)
# float32) as ``k_scale``/``v_scale`` and dequantizes the gathered spans to
# float32 *before* the score einsum — the same contract the Pallas kernels
# honour in VMEM.
# ---------------------------------------------------------------------------
def _apply_block_scales(spans: jax.Array, scale_pool: jax.Array,
                        tables: jax.Array) -> jax.Array:
    """Dequantize gathered int8 KV spans (rows, S, Hkv, D) with the scale
    spans gathered through the same block tables."""
    rows = tables.shape[0]
    sc = scale_pool[tables].reshape(rows, -1, scale_pool.shape[2])
    return spans.astype(jnp.float32) * sc[..., None]


def paged_attention_chunk_reference(q: jax.Array, k_pool: jax.Array,
                                    v_pool: jax.Array,
                                    block_tables: jax.Array,
                                    q_starts: jax.Array, *,
                                    window: int = 0,
                                    k_scale: jax.Array = None,
                                    v_scale: jax.Array = None) -> jax.Array:
    """q: (B, C, H, D) a chunk of C query tokens per lane; pools:
    (num_blocks, bs, Hkv, D); block_tables: (B, max_blocks) int32;
    q_starts: (B,) absolute position of each lane's first chunk token.
    Returns (B, C, H, D).

    Logical kv position t of lane b lives in physical block
    ``block_tables[b, t // bs]`` at offset ``t % bs``; query c masks kv
    positions past ``q_starts[b] + c`` (and outside the sliding window) —
    causal masking inside the chunk.  Padded queries (beyond a lane's real
    chunk length) produce finite garbage the caller ignores.
    """
    B, C, H, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    max_blocks = block_tables.shape[1]
    G = H // Hkv
    k = k_pool[block_tables].reshape(B, max_blocks * bs, Hkv, D)
    v = v_pool[block_tables].reshape(B, max_blocks * bs, Hkv, D)
    if k_scale is not None:
        k = _apply_block_scales(k, k_scale, block_tables)
        v = _apply_block_scales(v, v_scale, block_tables)
    qg = q.reshape(B, C, Hkv, G, D)
    s = jnp.einsum("bckgd,bskd->bckgs", qg, k).astype(jnp.float32)
    s = s / (D ** 0.5)
    qpos = q_starts[:, None] + jnp.arange(C)[None, :]          # (B, C)
    kpos = jnp.arange(max_blocks * bs)[None, None, :]
    valid = kpos <= qpos[:, :, None]
    if window:
        valid &= (qpos[:, :, None] - kpos) < window
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bckgs,bskd->bckgd", w, v)
    return out.reshape(B, C, H, D)


def paged_attention_ragged_reference(q: jax.Array, k_pool: jax.Array,
                                     v_pool: jax.Array,
                                     token_tables: jax.Array,
                                     token_pos: jax.Array, *,
                                     window: int = 0,
                                     k_scale: jax.Array = None,
                                     v_scale: jax.Array = None) -> jax.Array:
    """q: (T, H, D) — one flattened stream of query tokens drawn from many
    lanes (mixed prefill chunks and decodes, no per-lane rectangle);
    pools: (num_blocks, bs, Hkv, D); token_tables: (T, max_blocks) int32 —
    row t is the block-table row of the lane that owns token t;
    token_pos: (T,) int32 — token t's absolute position in its own
    sequence.  Returns (T, H, D).

    Token t attends to kv positions ``<= token_pos[t]`` of its own lane's
    blocks (and inside the sliding window).  In-chunk causality falls out
    of the per-token positions: two tokens of the same lane in the same
    flat batch see each other iff the earlier one's position is lower.
    Work is proportional to T — the number of *real* scheduled tokens —
    instead of ``lanes * max(q_len)``.  Padding tokens (null tables,
    position 0) produce finite garbage the caller ignores.
    """
    T, H, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    max_blocks = token_tables.shape[1]
    G = H // Hkv
    # one span gather PER TOKEN — the traffic the tiled oracle below kills
    k = _gather_block_spans(k_pool, token_tables)
    v = _gather_block_spans(v_pool, token_tables)
    if k_scale is not None:
        k = _apply_block_scales(k, k_scale, token_tables)
        v = _apply_block_scales(v, v_scale, token_tables)
    qg = q.reshape(T, Hkv, G, D)
    s = jnp.einsum("tkgd,tskd->tkgs", qg, k).astype(jnp.float32)
    s = s / (D ** 0.5)
    kpos = jnp.arange(max_blocks * bs)[None, :]                # (1, S)
    valid = kpos <= token_pos[:, None]
    if window:
        valid &= (token_pos[:, None] - kpos) < window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("tkgs,tskd->tkgd", w, v)
    return out.reshape(T, H, D)


# ---------------------------------------------------------------------------
# segment-tiled ragged oracle — KV gathered once per lane *span*, not once
# per token.
#
# The per-token ragged reference above materializes token_tables-many
# (max_blocks * bs) KV spans: a 256-token prefill re-gathers its lane's
# blocks 256 times, which made all-prefill workloads ~30% slower than the
# rectangular path on CPU.  The tiled form reads the pool once per *lane*
# (k_pool[tables], each block touched once per step) and then computes
# attention per q-row tile, so gather traffic scales with tiles + lanes
# instead of tokens.  Tile metadata contract (shared with the Pallas
# kernel and serving.batch.TileMap): ``tile_meta`` is (5, n_tiles) int32
# with rows indexed by the TILE_* constants below; ``row_tile`` (T,) maps
# every flat row to its owning tile.
# ---------------------------------------------------------------------------
TILE_WINDOW, TILE_LO, TILE_HI, TILE_POS0, TILE_LANE = range(5)

# pool-read instrumentation: every eager call of the span gather adds the
# number of (row, block) pairs it materializes.  Tests assert the tiled
# reference's reads scale with lanes/tiles while the per-token form scales
# with tokens; under jit the count reflects one trace, so instrumented
# tests call the references eagerly.
pool_gather_stats = {"blocks": 0}


def _gather_block_spans(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """The one place reference oracles read the KV pool: row r of the
    result is the gathered span ``pool[tables[r]]`` flattened to
    (rows, max_blocks * bs, Hkv, D)."""
    rows, max_blocks = tables.shape
    pool_gather_stats["blocks"] += rows * max_blocks
    _, bs, Hkv, D = pool.shape
    return pool[tables].reshape(rows, max_blocks * bs, Hkv, D)


def paged_attention_ragged_tiled_reference(
        q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
        tables: jax.Array, tile_meta: jax.Array, row_tile: jax.Array, *,
        tile: int, window: int = 0, k_scale: jax.Array = None,
        v_scale: jax.Array = None) -> jax.Array:
    """q: (T, H, D) — the same flat stream as
    :func:`paged_attention_ragged_reference`, but attended through the
    segment-tiled metadata: ``tables`` (n_lanes, max_blocks) per-lane block
    rows, ``tile_meta`` (5, n_tiles) int32 (TILE_* rows), ``row_tile`` (T,)
    the owning tile of every flat row.  Returns (T, H, D), bit-identical
    to the per-token oracle on every real row.

    Each lane's KV span is gathered from the pool exactly once; tile t
    then attends its q rows ``[lo, hi)`` (a slab of window
    ``tile_meta[TILE_WINDOW, t]``) against its lane's span with the causal
    bound ``pos0 + (row - lo)``.  Rows of a window outside the tile's
    segment are masked out; inert capacity-padding tiles (lo == hi) and
    stream-padding rows produce finite garbage the caller ignores.
    """
    T, H, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    G = H // Hkv
    S = tables.shape[1] * bs
    n_windows = -(-T // tile)
    pad = n_windows * tile - T
    qw = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    qw = qw.reshape(n_windows, tile, Hkv, G, D)
    k_lanes = _gather_block_spans(k_pool, tables)      # (n_lanes, S, Hkv, D)
    v_lanes = _gather_block_spans(v_pool, tables)
    if k_scale is not None:
        k_lanes = _apply_block_scales(k_lanes, k_scale, tables)
        v_lanes = _apply_block_scales(v_lanes, v_scale, tables)
    win, lo, hi = tile_meta[TILE_WINDOW], tile_meta[TILE_LO], \
        tile_meta[TILE_HI]
    pos0, lane = tile_meta[TILE_POS0], tile_meta[TILE_LANE]
    qt = qw[win]                                   # (n_tiles, tile, Hkv, G, D)
    kt = k_lanes[lane]                             # (n_tiles, S, Hkv, D)
    vt = v_lanes[lane]
    s = jnp.einsum("ntkgd,nskd->ntkgs", qt, kt).astype(jnp.float32)
    s = s / (D ** 0.5)
    rows = win[:, None] * tile + jnp.arange(tile)[None, :]   # (n_tiles, tile)
    qpos = pos0[:, None] + rows - lo[:, None]
    rowvalid = (rows >= lo[:, None]) & (rows < hi[:, None])
    kpos = jnp.arange(S)[None, None, :]
    valid = rowvalid[:, :, None] & (kpos <= qpos[:, :, None])
    if window:
        valid &= (qpos[:, :, None] - kpos) < window
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    ot = jnp.einsum("ntkgs,nskd->ntkgd", w, vt)    # (n_tiles, tile, Hkv, G, D)
    r = jnp.arange(T)
    t_idx = row_tile[:T]
    off = jnp.clip(r - win[t_idx] * tile, 0, tile - 1)
    return ot[t_idx, off].reshape(T, H, D)


def paged_attention_reference(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, block_tables: jax.Array,
                              ctx_lens: jax.Array, *,
                              window: int = 0,
                              k_scale: jax.Array = None,
                              v_scale: jax.Array = None) -> jax.Array:
    """q: (B, H, D) one query token per lane at position ``ctx_lens - 1``;
    the decode special case of :func:`paged_attention_chunk_reference`.
    Returns (B, H, D)."""
    out = paged_attention_chunk_reference(
        q[:, None], k_pool, v_pool, block_tables, ctx_lens - 1,
        window=window, k_scale=k_scale, v_scale=v_scale)
    return out[:, 0]


# ---------------------------------------------------------------------------
# ssd_scan oracle — direct (non-chunked) linear recurrence
# ---------------------------------------------------------------------------
def ssd_reference(xdt: jax.Array, dA: jax.Array, Bm: jax.Array,
                  Cm: jax.Array) -> jax.Array:
    """Sequential SSM recurrence, the ground truth for the chunked forms.

    xdt: (B,H,L,P); dA: (B,H,L); Bm/Cm: (B,G,L,N) -> y (B,H,L,P)
    h_t = exp(dA_t) h_{t-1} + xdt_t B_t^T ;  y_t = h_t C_t
    """
    B, H, L, P = xdt.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)    # (B,H,L,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    def step(h, inp):
        x_t, dA_t, B_t, C_t = inp        # (B,H,P), (B,H), (B,H,N), (B,H,N)
        h = h * jnp.exp(dA_t)[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x_t, B_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xdt.astype(jnp.float32), 2, 0),
          jnp.moveaxis(dA.astype(jnp.float32), 2, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 2, 0),
          jnp.moveaxis(Ch.astype(jnp.float32), 2, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(xdt.dtype)   # (B,H,L,P)


# ---------------------------------------------------------------------------
# pseudo_voigt oracle — separable marginal Gauss-Newton fit
# ---------------------------------------------------------------------------
import math

_ETA = 0.5                      # fixed Lorentzian fraction
_C = 1.0 / math.sqrt(2.0 * math.log(2.0))   # sigma = _C * gamma


def pv_profile(u: jax.Array, gamma: jax.Array) -> jax.Array:
    """Unit-amplitude pseudo-Voigt profile at offsets u."""
    g2 = gamma * gamma
    lor = g2 / (u * u + g2)
    sig = _C * gamma
    gau = jnp.exp(-(u * u) / (2.0 * sig * sig))
    return _ETA * lor + (1.0 - _ETA) * gau


def _pv_grads(u, gamma):
    g2 = gamma * gamma
    lor = g2 / (u * u + g2)
    sig = _C * gamma
    gau = jnp.exp(-(u * u) / (2.0 * sig * sig))
    d_lor_dx0 = 2.0 * u * lor * lor / g2
    d_gau_dx0 = gau * u / (sig * sig)
    d_lor_dg = 2.0 * u * u * lor * lor / (g2 * gamma)
    d_gau_dg = gau * u * u / (_C * _C * gamma ** 3)
    dp_dx0 = _ETA * d_lor_dx0 + (1 - _ETA) * d_gau_dx0
    dp_dg = _ETA * d_lor_dg + (1 - _ETA) * d_gau_dg
    p = _ETA * lor + (1 - _ETA) * gau
    return p, dp_dx0, dp_dg


def pv_fit_1d(y: jax.Array, n_iter: int = 5,
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fit A * pV(x - x0; gamma) + bg to y (..., n) by Gauss-Newton.

    Returns (x0, gamma, A).  bg is the per-profile min (subtracted, not fit).
    """
    n = y.shape[-1]
    x = jnp.arange(n, dtype=jnp.float32)
    yf = y.astype(jnp.float32)
    bg = yf.min(axis=-1, keepdims=True)
    yc = yf - bg
    total = jnp.maximum(yc.sum(axis=-1), 1e-12)

    x0 = (yc * x).sum(axis=-1) / total
    var = (yc * (x - x0[..., None]) ** 2).sum(axis=-1) / total
    gamma = jnp.sqrt(jnp.maximum(var, 0.25))
    A = jnp.maximum(yc.max(axis=-1), 1e-12)

    for _ in range(n_iter):
        u = x - x0[..., None]
        p, dp_dx0, dp_dg = _pv_grads(u, gamma[..., None])
        f = A[..., None] * p
        r = yc - f
        # jacobian columns: dA, dx0, dgamma
        j0 = p
        j1 = A[..., None] * dp_dx0
        j2 = A[..., None] * dp_dg
        # normal equations (3x3), solved in closed form
        a00 = (j0 * j0).sum(-1); a01 = (j0 * j1).sum(-1); a02 = (j0 * j2).sum(-1)
        a11 = (j1 * j1).sum(-1); a12 = (j1 * j2).sum(-1); a22 = (j2 * j2).sum(-1)
        b0 = (j0 * r).sum(-1); b1 = (j1 * r).sum(-1); b2 = (j2 * r).sum(-1)
        # regularize
        lam = 1e-6 * (a00 + a11 + a22) + 1e-12
        a00 = a00 + lam; a11 = a11 + lam; a22 = a22 + lam
        det = (a00 * (a11 * a22 - a12 * a12)
               - a01 * (a01 * a22 - a12 * a02)
               + a02 * (a01 * a12 - a11 * a02))
        det = jnp.where(jnp.abs(det) < 1e-20, 1e-20, det)
        i00 = a11 * a22 - a12 * a12
        i01 = a02 * a12 - a01 * a22
        i02 = a01 * a12 - a02 * a11
        i11 = a00 * a22 - a02 * a02
        i12 = a02 * a01 - a00 * a12
        i22 = a00 * a11 - a01 * a01
        dA = (i00 * b0 + i01 * b1 + i02 * b2) / det
        dx0 = (i01 * b0 + i11 * b1 + i12 * b2) / det
        dg = (i02 * b0 + i12 * b1 + i22 * b2) / det
        A = jnp.maximum(A + dA, 1e-12)
        x0 = jnp.clip(x0 + dx0, 0.0, n - 1.0)
        gamma = jnp.clip(gamma + dg, 0.3, float(n))
    return x0, gamma, A


def pseudo_voigt_reference(patches: jax.Array, n_iter: int = 5) -> jax.Array:
    """patches (Np, ph, pw) -> (Np, 6): (y0, x0, gy, gx, Ay, Ax).

    Separable fit: pseudo-Voigt GN on the row- and column-marginals.
    """
    my = patches.sum(axis=2)   # (Np, ph)  marginal over columns -> y profile
    mx = patches.sum(axis=1)   # (Np, pw)
    y0, gy, Ay = pv_fit_1d(my, n_iter)
    x0, gx, Ax = pv_fit_1d(mx, n_iter)
    return jnp.stack([y0, x0, gy, gx, Ay, Ax], axis=-1)
