"""Pallas TPU Mamba2/SSD chunked-scan kernel.

TPU-native schedule: grid = (batch, heads, num_chunks); the chunk axis is the
minor (sequential) grid dimension, so the recurrent SSM state (P x N) lives in
VMEM scratch and is carried across chunks — the inter-chunk recurrence costs
no HBM round-trip.  Per chunk the kernel computes, entirely in VMEM:

    cum   = cumsum(dA)                         (Q,)      decay within chunk
    Lmat  = tril(exp(cum_i - cum_j))           (Q, Q)    intra-chunk decays
    CB    = C @ B^T                            (Q, Q)    MXU
    y     = (CB * Lmat) @ xdt                  (Q, P)    MXU   [intra]
          + exp(cum)[:,None] * (C @ state^T)   (Q, P)    MXU   [inter]
    state = exp(cum[-1]) * state + xdt^T @ (B * exp(cum[-1]-cum))   [update]

Inputs are pre-projected per head (the wrapper in ops.py pre-multiplies
x by dt and folds A into dA = dt * A_h), so the kernel is pure scan math.
Oracle: kernels/ref.py::ssd_reference (also exercised against
models/ssm.py::ssd_chunked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dA = dA_ref[0, 0].astype(jnp.float32)         # (Q,) negative
    Bm = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(dA)                          # (Q,)

    # intra-chunk decay matrix
    diff = cum[:, None] - cum[None, :]            # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    W = CB * Lmat                                  # (Q, Q)
    y = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: carried state contribution
    state = state_scr[...]                         # (P, N)
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + jnp.exp(cum)[:, None] * y_off

    # state update
    decay_last = jnp.exp(cum[-1] - cum)            # (Q,)
    Bd = Bm * decay_last[:, None]                  # (Q, N)
    upd = jax.lax.dot_general(xdt, Bd, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = jnp.exp(cum[-1]) * state + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array, *,
             chunk: int = 128, interpret: bool = False) -> jax.Array:
    """Chunked SSD scan.

    xdt: (B, H, L, P)  inputs pre-multiplied by dt
    dA:  (B, H, L)     dt * A_h (negative)
    Bm:  (B, G, L, N)  input map (groups broadcast to heads via index_map)
    Cm:  (B, G, L, N)  output map
    Returns y (B, H, L, P).
    """
    B, H, L, P = xdt.shape
    G, N = Bm.shape[1], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    rep = H // G

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
