from repro.optim.optimizers import (adafactor, adam, adamw, global_norm,  # noqa: F401
                                    Optimizer, sgd)
from repro.optim import schedules  # noqa: F401
