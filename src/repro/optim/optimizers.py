"""Optimizers (from scratch — no optax): Adam(W), SGD+momentum, Adafactor-lite.

Functional API:
    opt = adamw(lr=1e-3, ...)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params)

Optimizer state trees mirror the parameter tree, so the launcher can apply
identical PartitionSpecs to both (FSDP-style sharded optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def _tree_zeros_like(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


# ---------------------------------------------------------------------------
def adamw(lr: float | Callable[[jax.Array], jax.Array] = 1e-3,
          b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0,
          grad_clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": _tree_zeros_like(params),
            "v": _tree_zeros_like(params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip_norm is not None:
            gnorm = global_norm(gf)
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], gf)
        lr_t = lr(step) if callable(lr) else lr
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            d = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr=1e-3, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


# ---------------------------------------------------------------------------
def sgd(lr: float | Callable = 1e-2, momentum: float = 0.9,
        nesterov: bool = False,
        grad_clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": _tree_zeros_like(params)}

    def update(grads, state, params):
        step = state["step"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip_norm is not None:
            gnorm = global_norm(gf)
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        mu = jax.tree.map(lambda mu_, g: momentum * mu_ + g,
                          state["mu"], gf)
        lr_t = lr(step) if callable(lr) else lr

        def upd(p, mu_, g):
            d = momentum * mu_ + g if nesterov else mu_
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, gf)
        return new_params, {"step": step, "mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
def adafactor(lr: float | Callable = 1e-2, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (memory-lean for huge models)."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "slots": jax.tree.map(per_leaf, params,
                                      is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8
        lr_t = lr(step) if callable(lr) else lr

        def per_leaf(g, slot, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in slot:
                vr = beta2 * slot["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * slot["vc"] + (1 - beta2) * g2.mean(axis=-2)
                rmean = vr.mean(axis=-1, keepdims=True)
                u = g / jnp.sqrt(
                    jnp.expand_dims(vr / jnp.maximum(rmean, eps), -1)
                    * jnp.expand_dims(vc, -2) + eps)
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v + eps)
                new_slot = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            newp = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return newp, new_slot

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        outs = [per_leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_slots = treedef.unflatten([o[1] for o in outs])
        return new_params, {"step": step, "slots": new_slots}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
