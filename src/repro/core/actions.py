"""Concrete action providers wiring the flow engine to the services.

These mirror the paper's Figure 2: every compute function (simulate, label,
train) is a funcX function wrapped as a Flows action; every data dependency
is a Globus transfer wrapped as an action; model delivery is a transfer +
model-repository registration (the paper's future-work item 1, implemented
here).
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.auth import SCOPE_COMPUTE, SCOPE_TRANSFER
from repro.core.flows import ActionFailure, ActionProvider, RunContext
from repro.core.funcx import FuncXService
from repro.core.registry import ModelRepository
from repro.core.transfer import DataStore, TransferService


class TransferProvider(ActionProvider):
    """Parameters: src, dst, names[, concurrency, label]."""

    name = "transfer"
    required_scope = SCOPE_TRANSFER

    def __init__(self, transfer: TransferService) -> None:
        self.transfer = transfer

    def run(self, params: Dict[str, Any], ctx: RunContext) -> Any:
        try:
            rec = self.transfer.submit(
                params["src"], params["dst"], list(params["names"]),
                concurrency=params.get("concurrency"),
                label=params.get("label", ""))
        except KeyError as e:
            raise ActionFailure(f"missing file or parameter: {e}")
        return {
            "task_id": rec.task_id,
            "nbytes": rec.nbytes,
            "duration": rec.duration,
            "rate_Bps": rec.rate,
            "retries": rec.retries,
        }


class ComputeProvider(ActionProvider):
    """Parameters: endpoint_id, function_id, args (list), kwargs (dict)
    [, modeled_duration, label]."""

    name = "compute"
    required_scope = SCOPE_COMPUTE

    def __init__(self, funcx: FuncXService) -> None:
        self.funcx = funcx

    def run(self, params: Dict[str, Any], ctx: RunContext) -> Any:
        try:
            tr = self.funcx.run(
                params["endpoint_id"], params["function_id"],
                *params.get("args", []),
                modeled_duration=params.get("modeled_duration"),
                label=params.get("label", ""),
                **params.get("kwargs", {}))
        except KeyError as e:
            raise ActionFailure(f"unknown endpoint/function: {e}")
        except Exception as e:  # compute errors are action failures
            raise ActionFailure(f"compute raised {type(e).__name__}: {e}")
        return {
            "task_id": tr.task_id,
            "result": tr.result,
            "duration": tr.duration,
            "overhead": tr.overhead,
            "mode": tr.mode,
        }


class RegisterModelProvider(ActionProvider):
    """Registers a delivered model artifact in the model repository.

    Parameters: name, version_tag, facility, artifact_name[, metrics].
    """

    name = "register_model"
    required_scope = SCOPE_COMPUTE

    def __init__(self, repo: ModelRepository, store: DataStore) -> None:
        self.repo = repo
        self.store = store

    def run(self, params: Dict[str, Any], ctx: RunContext) -> Any:
        fac = params["facility"]
        art = params["artifact_name"]
        if not self.store.exists(fac, art):
            raise ActionFailure(f"artifact {art!r} not present at {fac!r}")
        ref = self.store.get(fac, art)
        entry = self.repo.register(
            params["name"], params.get("version_tag", ""), ref,
            metrics=params.get("metrics", {}))
        return {"name": entry.name, "version": entry.version,
                "nbytes": ref.nbytes}


class OverlapLabelTrainProvider(ActionProvider):
    """Future-work #3 as a flow action: pipelined A||T on the DC.

    Parameters: facility, dataset_name, label_function, train_init_function,
    train_shard_function (funcX function ids registered on the service),
    n_shards, artifact_name.
    """

    name = "overlap_label_train"
    required_scope = SCOPE_COMPUTE

    def __init__(self, funcx, store: DataStore) -> None:
        self.funcx = funcx
        self.store = store

    def run(self, params: Dict[str, Any], ctx: RunContext) -> Any:
        from repro.core.pipeline_flow import run_overlapped_label_train

        fx = self.funcx
        try:
            label_fn = fx.functions[params["label_function"]]
            init_fn = fx.functions[params["train_init_function"]]
            shard_fn = fx.functions[params["train_shard_function"]]
            sys_like = ctx.services["system"]
            res = run_overlapped_label_train(
                sys_like,
                dataset_facility=params["facility"],
                dataset_name=params["dataset_name"],
                label_fn=label_fn, train_init_fn=init_fn,
                train_shard_fn=shard_fn,
                n_shards=int(params.get("n_shards", 8)),
                artifact_name=params.get("artifact_name", "model.npz"))
        except KeyError as e:
            raise ActionFailure(f"missing parameter/function: {e}")
        return {
            "serial_s": res["serial_s"],
            "pipelined_s": res["pipelined_s"],
            "saving_s": res["saving_s"],
            "metrics": res["metrics"],
        }
