"""Globus-Flows-like declarative workflow engine.

A *Flow* is a declaratively defined ordering of *Action Providers* with
condition handling (paper §3).  Flows are deployed once (getting a flow id)
and run many times with different inputs — "similar as running a function
with different arguments" (paper appendix §1.2).

Definition format (a plain dict, like the Automate SDK):

    {
      "StartAt": "TransferData",
      "States": {
        "TransferData": {
          "Provider": "transfer",
          "Parameters": {"src": "$.input.src", "dst": "$.input.dc",
                          "names": "$.input.dataset"},
          "Next": "Train",
          "Retries": 2,
          "OnFailure": "NotifyUser"
        },
        "Train": {...},
        ...
        "Done": {"End": true, ...}
      }
    }

``$.``-prefixed strings are JSONPath-style references resolved against
``{"input": <run input>, "results": {<state>: <action result>}}``; lists and
nested dicts are resolved recursively.  Each action execution is timed on the
shared :class:`SimClock` and recorded in the run log — the log is exactly the
per-step breakdown reported in the paper's Table 1.
"""
from __future__ import annotations

import dataclasses
import uuid
from typing import Any, Callable, Dict, List, Optional

from repro.core.auth import AuthError, AuthService, SCOPE_FLOWS, Token
from repro.core.simclock import SimClock


class ActionFailure(Exception):
    """Raised by providers to signal a (possibly retryable) action failure."""


class FlowError(Exception):
    pass


# ---------------------------------------------------------------------------
# Action providers
# ---------------------------------------------------------------------------
class ActionProvider:
    """An HTTP-accessible service acting as a single step in a process."""

    name: str = "base"
    required_scope: str = SCOPE_FLOWS
    #: service-side latency per invocation (HTTP + auth round trips)
    invocation_overhead: float = 0.2

    def run(self, params: Dict[str, Any], ctx: "RunContext") -> Any:
        raise NotImplementedError


@dataclasses.dataclass
class RunContext:
    clock: SimClock
    token: Token
    services: Dict[str, Any]


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ActionExecution:
    state: str
    provider: str
    started_at: float
    duration: float
    status: str                 # "SUCCEEDED" | "FAILED"
    attempts: int
    result: Any = None
    error: str = ""


@dataclasses.dataclass
class FlowRun:
    run_id: str
    flow_id: str
    status: str
    log: List[ActionExecution]
    output: Dict[str, Any]
    turnaround: float

    def step_seconds(self) -> Dict[str, float]:
        return {e.state: e.duration for e in self.log}


# ---------------------------------------------------------------------------
def _resolve(value: Any, scope: Dict[str, Any]) -> Any:
    if isinstance(value, str) and value.startswith("$."):
        node: Any = scope
        for part in value[2:].split("."):
            if isinstance(node, dict):
                node = node[part]
            else:
                node = getattr(node, part)
        return node
    if isinstance(value, dict):
        return {k: _resolve(v, scope) for k, v in value.items()}
    if isinstance(value, list):
        return [_resolve(v, scope) for v in value]
    return value


class FlowsService:
    def __init__(self, clock: SimClock, auth: AuthService,
                 providers: Dict[str, ActionProvider],
                 services: Optional[Dict[str, Any]] = None) -> None:
        self.clock = clock
        self.auth = auth
        self.providers = providers
        self.services = services or {}
        self._flows: Dict[str, Dict] = {}

    # ------------------------------------------------------------------
    def deploy(self, definition: Dict) -> str:
        if "StartAt" not in definition or "States" not in definition:
            raise FlowError("definition needs StartAt and States")
        start = definition["StartAt"]
        states = definition["States"]
        if start not in states:
            raise FlowError(f"StartAt {start!r} not in States")
        for name, st in states.items():
            if "Provider" in st and st["Provider"] not in self.providers:
                raise FlowError(f"unknown provider {st['Provider']!r}"
                                f" in state {name!r}")
            if st.get("End"):
                continue
            nxt = st.get("Next")
            if nxt is not None and nxt not in states:
                raise FlowError(f"state {name!r} Next -> unknown {nxt!r}")
            fb = st.get("OnFailure")
            if fb is not None and fb not in states:
                raise FlowError(f"state {name!r} OnFailure -> unknown {fb!r}")
        fid = f"flow-{uuid.uuid4().hex[:12]}"
        self._flows[fid] = definition
        return fid

    # ------------------------------------------------------------------
    def run(self, flow_id: str, flow_input: Dict[str, Any],
            token: Token) -> FlowRun:
        self.auth.validate(token)
        token.require(SCOPE_FLOWS)
        definition = self._flows[flow_id]
        states = definition["States"]
        scope: Dict[str, Any] = {"input": flow_input, "results": {}}
        ctx = RunContext(self.clock, token, self.services)

        log: List[ActionExecution] = []
        t_start = self.clock.now
        current: Optional[str] = definition["StartAt"]
        status = "SUCCEEDED"
        guard = 0

        while current is not None:
            guard += 1
            if guard > 1000:
                raise FlowError("flow exceeded 1000 state transitions")
            st = states[current]
            if st.get("End") and "Provider" not in st:
                break
            provider = self.providers[st["Provider"]]
            retries = int(st.get("Retries", 0))
            attempts = 0
            started = self.clock.now
            result, err = None, ""
            while True:
                attempts += 1
                try:
                    self.auth.validate(token)
                    token.require(provider.required_scope)
                    self.clock.advance(provider.invocation_overhead,
                                       f"{current} [provider http]", "sim")
                    params = _resolve(st.get("Parameters", {}), scope)
                    result = provider.run(params, ctx)
                    ok = True
                    break
                except (ActionFailure, AuthError, KeyError) as e:  # noqa: PERF203
                    err = f"{type(e).__name__}: {e}"
                    ok = False
                    if attempts > retries:
                        break
            exec_rec = ActionExecution(
                state=current, provider=st["Provider"], started_at=started,
                duration=self.clock.now - started,
                status="SUCCEEDED" if ok else "FAILED",
                attempts=attempts, result=result, error=err)
            log.append(exec_rec)
            scope["results"][current] = result

            if ok:
                current = st.get("Next")
                if current is None and not st.get("End", False):
                    break
            else:
                fb = st.get("OnFailure")
                if fb is None:
                    status = "FAILED"
                    break
                current = fb

        return FlowRun(
            run_id=f"run-{uuid.uuid4().hex[:12]}",
            flow_id=flow_id,
            status=status,
            log=log,
            output=scope["results"],
            turnaround=self.clock.now - t_start,
        )
