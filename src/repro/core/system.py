"""One-call assembly of the paper's full distributed system (Figure 2).

``build_system()`` wires topology + clock + auth + data store + transfer +
funcX + model repository + flow engine, and ``dnn_trainer_flow()`` returns
the paper's DNNTrainerFlow definition:

    TransferData (ex->dc)  ->  LabelData (A at dc, optional)
      ->  TrainModel (T on the DCAI endpoint)
      ->  TransferModel (dc->ex)  ->  RegisterModel (edge repo)

which is exactly the Table-1 measured pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.actions import (ComputeProvider, OverlapLabelTrainProvider,
                                RegisterModelProvider, TransferProvider)
from repro.core.auth import (AuthService, SCOPE_COMPUTE, SCOPE_FLOWS,
                             SCOPE_TRANSFER)
from repro.core.costmodel import CostModel, OperationCosts
from repro.core.facility import Topology, paper_topology
from repro.core.flows import FlowsService
from repro.core.funcx import FuncXService
from repro.core.registry import ModelRepository
from repro.core.simclock import SimClock
from repro.core.transfer import DataStore, TransferService


@dataclasses.dataclass
class System:
    topo: Topology
    clock: SimClock
    auth: AuthService
    store: DataStore
    transfer: TransferService
    funcx: FuncXService
    repo: ModelRepository
    flows: FlowsService
    costmodel: CostModel

    def user_token(self, subject: str = "scientist"):
        return self.auth.issue(
            subject, [SCOPE_FLOWS, SCOPE_TRANSFER, SCOPE_COMPUTE])


def build_system(*, fault_rate: float = 0.0, seed: int = 0,
                 topo: Optional[Topology] = None,
                 costs: Optional[OperationCosts] = None) -> System:
    topo = topo or paper_topology()
    clock = SimClock()
    auth = AuthService()
    store = DataStore()
    transfer = TransferService(topo, clock, store, fault_rate=fault_rate,
                               seed=seed)
    funcx = FuncXService(topo, clock)
    repo = ModelRepository()
    providers = {
        "transfer": TransferProvider(transfer),
        "compute": ComputeProvider(funcx),
        "register_model": RegisterModelProvider(repo, store),
        "overlap_label_train": OverlapLabelTrainProvider(funcx, store),
    }
    flows = FlowsService(clock, auth, providers,
                         services={"store": store, "repo": repo})
    cm = CostModel(topo, transfer, costs)
    system = System(topo, clock, auth, store, transfer, funcx, repo, flows,
                    cm)
    flows.services["system"] = system
    return system


# ---------------------------------------------------------------------------
def dnn_trainer_flow(*, with_labeling: bool = False) -> Dict[str, Any]:
    """The paper's DNNTrainerFlow definition (github.com/AISDC/DNNTrainerFlow).

    Run-time arguments (flow input):
      src, dc: facility names;  dataset: list of file names;
      train_endpoint, train_function: funcX ids;  train_args/kwargs;
      modeled_duration (optional);  model_name: artifact file name produced
      by the train function;  register_as: repository model name.
    """
    states: Dict[str, Any] = {
        "TransferData": {
            "Provider": "transfer",
            "Parameters": {
                "src": "$.input.src",
                "dst": "$.input.dc",
                "names": "$.input.dataset",
                "label": "dataset ex->dc",
            },
            "Retries": 2,
            "Next": "LabelData" if with_labeling else "TrainModel",
        },
        "TrainModel": {
            "Provider": "compute",
            "Parameters": {
                "endpoint_id": "$.input.train_endpoint",
                "function_id": "$.input.train_function",
                "args": "$.input.train_args",
                "kwargs": "$.input.train_kwargs",
                "modeled_duration": "$.input.modeled_duration",
                "label": "T: train on DCAI",
            },
            "Retries": 1,
            "Next": "TransferModel",
        },
        "TransferModel": {
            "Provider": "transfer",
            "Parameters": {
                "src": "$.input.dc",
                "dst": "$.input.src",
                "names": "$.input.model_artifacts",
                "label": "model dc->ex",
            },
            "Retries": 2,
            "Next": "RegisterModel",
        },
        "RegisterModel": {
            "Provider": "register_model",
            "Parameters": {
                "name": "$.input.register_as",
                "version_tag": "$.input.version_tag",
                "facility": "$.input.src",
                "artifact_name": "$.input.model_name",
                "metrics": "$.input.metrics",
            },
            "End": True,
        },
    }
    if with_labeling:
        states["LabelData"] = {
            "Provider": "compute",
            "Parameters": {
                "endpoint_id": "$.input.label_endpoint",
                "function_id": "$.input.label_function",
                "args": "$.input.label_args",
                "kwargs": "$.input.label_kwargs",
                "label": "A: conventional labeling",
            },
            "Retries": 1,
            "Next": "TrainModel",
        }
    return {"StartAt": "TransferData", "States": states}
