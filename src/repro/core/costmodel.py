"""The paper's analytical performance model (§4) — Equations (1)-(3).

Six primitive operations over a datum d:
  C(ollect), S(imulate), A(nalyze, conventional), T(rain), D(eploy),
  E(stimate with the ML surrogate);
locations as subscripts (ex = experiment facility, dc = data center); data
movement  a --d--> b  costed by the transfer service's linear model.

Strategies (per-datum costs in seconds unless noted):
  f_c(N)   Eq.(1): ship data to DC, analyze conventionally, ship results back
  f_ex(N)  Eq.(2): analyze conventionally at the experiment
  f_ml(N)  Eq.(3): ship a fraction p to DC, label it with A, train the
           surrogate T, ship the model back, Estimate the remaining (1-p)N

``crossover`` solves f_c(N) = f_ml(N) for N — the dataset size above which
the ML-surrogate pipeline wins (Fig. 4's crossing point).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.facility import Topology
from repro.core.transfer import TransferService


@dataclasses.dataclass(frozen=True)
class OperationCosts:
    """Per-datum / per-run operation costs (seconds).

    Defaults are the paper's §4.2 BraggNN/HEDM numbers:
      * A: 2000 core-seconds per 800K peaks on a 1024-core cluster
           -> 2.44 us/peak
      * E: 800K peaks in 280 ms batched -> 0.35 us/peak
      * datum: one 11x11 16-bit patch = 242 bytes -> 0.24 us at 1 GB/s
      * result bytes: 8 per datum (two fp32 coordinates)
      * T: 19 s on Cerebras (Table 1)
      * model: 3 MB BraggNN artifact
    """

    analyze_dc: float = 2.44e-6
    analyze_ex: float = 9.77e-6       # 4x fewer cores at the experiment
    estimate_ex: float = 0.35e-6
    collect: float = 0.0
    simulate: float = 0.0
    train: float = 19.0
    deploy: float = 0.5               # load model onto the edge device
    datum_bytes: int = 242
    result_bytes: int = 8
    model_bytes: int = 3_000_000


@dataclasses.dataclass(frozen=True)
class StrategyCost:
    total: float
    breakdown: Dict[str, float]

    def per_datum(self, n: int) -> float:
        return self.total / max(n, 1)


class CostModel:
    def __init__(self, topo: Topology, transfer: TransferService,
                 costs: Optional[OperationCosts] = None,
                 ex: str = "slac", dc: str = "alcf") -> None:
        self.topo = topo
        self.transfer = transfer
        self.costs = costs or OperationCosts()
        self.ex = ex
        self.dc = dc

    # -- helpers ---------------------------------------------------------
    def _move(self, src: str, dst: str, nbytes: int, n_files: int = 1
              ) -> float:
        return self.transfer.duration_model(src, dst, nbytes, n_files)

    # -- Eq. (1): conventional at the data center -------------------------
    def f_conventional_dc(self, n: int) -> StrategyCost:
        c = self.costs
        up = self._move(self.ex, self.dc, n * c.datum_bytes)
        analyze = n * c.analyze_dc
        down = self._move(self.dc, self.ex, n * c.result_bytes)
        return StrategyCost(up + analyze + down, {
            "data_up": up, "analyze": analyze, "results_down": down})

    # -- Eq. (2): conventional at the experiment --------------------------
    def f_conventional_ex(self, n: int) -> StrategyCost:
        analyze = n * self.costs.analyze_ex
        return StrategyCost(analyze, {"analyze": analyze})

    # -- Eq. (3): ML surrogate via remote DCAI ----------------------------
    def f_ml(self, n: int, p: float = 0.1, *,
             train_seconds: Optional[float] = None) -> StrategyCost:
        c = self.costs
        n_sub = int(p * n)
        up = self._move(self.ex, self.dc, n_sub * c.datum_bytes)
        label = n_sub * c.analyze_dc
        train = train_seconds if train_seconds is not None else c.train
        model_down = self._move(self.dc, self.ex, c.model_bytes)
        labels_down = self._move(self.dc, self.ex, n_sub * c.result_bytes)
        estimate = (n - n_sub) * c.estimate_ex
        total = up + label + train + model_down + labels_down + \
            c.deploy + estimate
        return StrategyCost(total, {
            "data_up": up, "label": label, "train": train,
            "model_down": model_down, "labels_down": labels_down,
            "deploy": c.deploy, "estimate": estimate})

    # -- Eq. (3') — paper future-work #3: overlap A (labeling) and T --------
    def f_ml_pipelined(self, n: int, p: float = 0.1, *,
                       train_seconds: Optional[float] = None,
                       n_microbatches: int = 16) -> StrategyCost:
        """Mini-batch training starts before all labels exist: A and T run
        as a software pipeline with ``n_microbatches`` stages; the critical
        path is max(A, T) plus one pipeline-fill stage of the other."""
        c = self.costs
        n_sub = int(p * n)
        up = self._move(self.ex, self.dc, n_sub * c.datum_bytes)
        label = n_sub * c.analyze_dc
        train = train_seconds if train_seconds is not None else c.train
        stage = 1.0 / max(n_microbatches, 1)
        overlapped = max(label, train) + stage * min(label, train)
        model_down = self._move(self.dc, self.ex, c.model_bytes)
        labels_down = self._move(self.dc, self.ex, n_sub * c.result_bytes)
        estimate = (n - n_sub) * c.estimate_ex
        total = up + overlapped + model_down + labels_down + \
            c.deploy + estimate
        return StrategyCost(total, {
            "data_up": up, "label_train_overlapped": overlapped,
            "model_down": model_down, "labels_down": labels_down,
            "deploy": c.deploy, "estimate": estimate})

    # -- crossover (Fig. 4) ------------------------------------------------
    def crossover(self, p: float = 0.1, lo: int = 1, hi: int = 10**10
                  ) -> Optional[int]:
        """Smallest N where f_ml(N) <= f_conventional_dc(N), or None."""
        f = lambda n: (self.f_ml(n, p).total
                       - self.f_conventional_dc(n).total)
        if f(hi) > 0:
            return None
        if f(lo) <= 0:
            return lo
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if f(mid) <= 0:
                hi = mid
            else:
                lo = mid
        return hi

    def advise(self, n: int, p: float = 0.1) -> str:
        """Pre-processing decision (paper: "can be used to decide which
        solution to take before processing")."""
        options = {
            "conventional_dc": self.f_conventional_dc(n).total,
            "conventional_ex": self.f_conventional_ex(n).total,
            "ml_surrogate": self.f_ml(n, p).total,
        }
        return min(options, key=options.get)
