"""Globus-transfer-like managed WAN transfer service.

Implements the paper's transfer cost model (§4.1):

    T = x / v + S        (x bytes, v effective rate, S startup cost)

with the Fig.-3 concurrency-dependent effective rate, per-task RTT-bound
control-channel overhead, optional fault injection with automatic retry
(Globus "fault recovery"), and checksum verification time.  Transfers are
charged to the :class:`SimClock`; payloads themselves move by reference
(the in-process data store hands the object to the destination).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Dict, List, Optional

from repro.core.facility import Topology
from repro.core.simclock import SimClock


@dataclasses.dataclass
class FileRef:
    """A named payload in a facility's data store."""

    name: str
    nbytes: int
    payload: Any = None


@dataclasses.dataclass
class TransferRecord:
    """Accounting for one completed transfer task.

    ``duration`` is the modeled seconds charged to the clock (including
    retry re-sends); ``rate`` the achieved bytes/s over that duration;
    ``n_files`` the logical file count the concurrency model priced.
    """

    task_id: str
    src: str
    dst: str
    nbytes: int
    n_files: int
    duration: float
    retries: int
    rate: float


class DataStore:
    """Per-facility named object store (stands in for the shared FS)."""

    def __init__(self) -> None:
        """Start with no facilities; they appear on first ``put``."""
        self._stores: Dict[str, Dict[str, FileRef]] = {}

    def put(self, facility: str, ref: FileRef) -> None:
        """Store ``ref`` under its name at ``facility`` (overwrites)."""
        self._stores.setdefault(facility, {})[ref.name] = ref

    def get(self, facility: str, name: str) -> FileRef:
        """Look up a named ref; KeyError when absent."""
        return self._stores[facility][name]

    def exists(self, facility: str, name: str) -> bool:
        """True when ``name`` is stored at ``facility``."""
        return name in self._stores.get(facility, {})


class TransferService:
    """Executes transfer tasks against the topology's cost model.

    Each :meth:`submit` resolves the source refs, prices the move with
    :meth:`duration_model`, charges the result to the shared
    :class:`SimClock`, and hands the payload refs to the destination's
    store.  Optional fault injection replays the Globus fault-recovery
    behaviour: a fault loses a random fraction of the task and the
    remainder is retried (up to 3 times), inflating the charged duration.
    """

    def __init__(self, topo: Topology, clock: SimClock, store: DataStore, *,
                 fault_rate: float = 0.0, seed: int = 0,
                 default_concurrency: int = 8) -> None:
        """Wire the service to a topology, clock and store.

        ``fault_rate`` is the per-attempt probability of a mid-transfer
        fault (deterministic under ``seed``); ``default_concurrency`` the
        stream count used when a submit does not specify one.
        """
        self.topo = topo
        self.clock = clock
        self.store = store
        self.fault_rate = fault_rate
        self.rng = random.Random(seed)
        self.default_concurrency = default_concurrency
        self.records: List[TransferRecord] = []
        self._task_counter = 0

    # ------------------------------------------------------------------
    def duration_model(self, src: str, dst: str, nbytes: int, n_files: int,
                       concurrency: Optional[int] = None) -> float:
        """The paper's linear model T = x/v + S (S scales with #files).

        ``v`` is the link's Fig.-3 concurrency-dependent effective rate for
        ``min(concurrency, n_files)`` parallel streams; the startup term
        pays ``per_file_startup`` once per batch of ``concurrency`` files,
        plus a 2*RTT control-channel round trip per task.
        """
        link = self.topo.link(src, dst)
        conc = concurrency or self.default_concurrency
        v = link.effective_rate(min(conc, n_files))
        startup = link.per_file_startup * ((n_files + conc - 1) // conc)
        control = 2 * link.rtt            # task submit + completion ack
        return nbytes / v + startup + control

    # ------------------------------------------------------------------
    def submit(self, src: str, dst: str, names: List[str], *,
               concurrency: Optional[int] = None,
               n_files: Optional[int] = None,
               label: str = "") -> TransferRecord:
        """Synchronously execute a transfer task (flows await them anyway).

        Moves the named refs from ``src``'s store to ``dst``'s and charges
        the modeled duration to the clock.  ``n_files`` overrides the
        logical file count used by the concurrency model — a single stored
        object may pack many wire-level files (e.g. a serialized KV-block
        shipment), and the override prices it as the multi-stream transfer
        it stands for.  Defaults to ``len(names)``.
        """
        refs = [self.store.get(src, n) for n in names]
        nbytes = sum(r.nbytes for r in refs)
        logical = n_files if n_files is not None else len(refs)
        logical = max(1, logical)
        base = self.duration_model(src, dst, nbytes, logical, concurrency)

        retries = 0
        total = 0.0
        while self.rng.random() < self.fault_rate and retries < 3:
            # fault mid-transfer: lose a random fraction, retry remainder
            frac = self.rng.uniform(0.1, 0.9)
            total += base * frac
            retries += 1
        total += base

        self._task_counter += 1
        task_id = f"xfer-{self._task_counter:05d}"
        self.clock.advance(total, label or f"{task_id} {src}->{dst}", "sim")
        for r in refs:
            self.store.put(dst, r)
        rec = TransferRecord(task_id, src, dst, nbytes, logical, total,
                             retries, nbytes / max(total, 1e-9))
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------------
    def throughput_curve(self, src: str, dst: str, nbytes: int,
                         concurrencies: List[int]) -> Dict[int, float]:
        """Fig.-3 benchmark helper: achieved rate vs concurrency."""
        out = {}
        for c in concurrencies:
            d = self.duration_model(src, dst, nbytes, n_files=max(c, 1),
                                    concurrency=c)
            out[c] = nbytes / d
        return out
