"""funcX-like federated function-as-a-service fabric.

Any facility device becomes a function-serving *endpoint*; functions are
registered once (getting a function id) and invoked fire-and-forget against
an endpoint id — exactly the paper's usage pattern (appendix §1.1).

Execution modes per endpoint:
  * ``real``    — run the registered Python function here, measure wall time
                  (used for edge/local steps and for real small-model DCAI
                  training in the examples);
  * ``modeled`` — run the function for its *result* (correctness) but charge
                  the clock a modeled duration: either a caller-supplied
                  estimate, or wall-time scaled by the endpoint's speedup
                  versus this host (used to model DCAI turnaround, clearly
                  tagged "modeled" in the clock log).

Service overheads (submission RTT, scheduler queue wait) are charged per
invocation from the device record, mirroring the paper's observation that
service overhead is a real part of end-to-end turnaround.
"""
from __future__ import annotations

import dataclasses
import time
import uuid
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.facility import ComputeDevice, Topology
from repro.core.simclock import SimClock


@dataclasses.dataclass
class Endpoint:
    endpoint_id: str
    device: ComputeDevice
    mode: str = "real"                    # "real" | "modeled"
    speedup_vs_host: float = 1.0          # used when mode == "modeled"


@dataclasses.dataclass
class TaskResult:
    task_id: str
    endpoint_id: str
    function_id: str
    result: Any
    duration: float          # seconds charged to the clock (compute only)
    overhead: float          # service + queue seconds charged
    mode: str


class FuncXService:
    def __init__(self, topo: Topology, clock: SimClock) -> None:
        self.topo = topo
        self.clock = clock
        self.functions: Dict[str, Callable] = {}
        self.endpoints: Dict[str, Endpoint] = {}
        self._task_counter = 0

    # ------------------------------------------------------------------
    def register_function(self, fn: Callable, name: str = "") -> str:
        fid = f"fn-{name or fn.__name__}-{uuid.uuid4().hex[:8]}"
        self.functions[fid] = fn
        return fid

    def register_endpoint(self, device_name: str, *, mode: str = "real",
                          speedup_vs_host: float = 1.0) -> str:
        dev = self.topo.device(device_name)
        eid = f"ep-{device_name}-{uuid.uuid4().hex[:8]}"
        self.endpoints[eid] = Endpoint(eid, dev, mode, speedup_vs_host)
        return eid

    # ------------------------------------------------------------------
    def run(self, endpoint_id: str, function_id: str, *args,
            modeled_duration: Optional[float] = None,
            label: str = "", **kwargs) -> TaskResult:
        ep = self.endpoints[endpoint_id]
        fn = self.functions[function_id]
        self._task_counter += 1
        task_id = f"task-{self._task_counter:05d}"
        lbl = label or f"{task_id} {function_id}@{ep.device.name}"

        overhead = ep.device.service_overhead + ep.device.queue_wait
        if overhead:
            self.clock.advance(overhead, lbl + " [service]", "sim")

        if ep.mode == "real":
            t0 = time.perf_counter()
            with self.clock.measure(lbl):
                result = fn(*args, **kwargs)
            duration = time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            result = fn(*args, **kwargs)
            wall = time.perf_counter() - t0
            duration = (modeled_duration if modeled_duration is not None
                        else wall / max(ep.speedup_vs_host, 1e-9))
            self.clock.charge(duration, lbl + " [modeled]")

        return TaskResult(task_id, endpoint_id, function_id, result,
                          duration, overhead, ep.mode)
