"""Model repository — the paper's future-work item 1, implemented.

"we are building the model repository ... so as to pick up the right model as
foundation to fine-tune using new dataset instead of retraining from scratch"
(paper §7).  Versioned artifacts with metrics; ``best_foundation`` picks the
highest-scoring compatible model to warm-start a retrain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.transfer import FileRef


@dataclasses.dataclass
class ModelEntry:
    name: str
    version: int
    version_tag: str
    artifact: FileRef
    metrics: Dict[str, float]


class ModelRepository:
    def __init__(self) -> None:
        self._models: Dict[str, List[ModelEntry]] = {}

    def register(self, name: str, version_tag: str, artifact: FileRef,
                 metrics: Optional[Dict[str, float]] = None) -> ModelEntry:
        versions = self._models.setdefault(name, [])
        entry = ModelEntry(name, len(versions) + 1, version_tag, artifact,
                           dict(metrics or {}))
        versions.append(entry)
        return entry

    def latest(self, name: str) -> ModelEntry:
        return self._models[name][-1]

    def get(self, name: str, version: int) -> ModelEntry:
        return self._models[name][version - 1]

    def versions(self, name: str) -> List[ModelEntry]:
        return list(self._models.get(name, []))

    def best_foundation(self, name: str, metric: str = "val_loss",
                        minimize: bool = True) -> Optional[ModelEntry]:
        """Pick the best prior model to fine-tune from (future-work #1)."""
        candidates = [e for e in self._models.get(name, [])
                      if metric in e.metrics]
        if not candidates:
            return None
        return (min if minimize else max)(
            candidates, key=lambda e: e.metrics[metric])


class DataRepository:
    """Data repository — the paper's future-work item 2, implemented.

    "we are also building a data repository to augment training dataset or
    substitute unlabelled dataset, because the labelling process is usually
    time consuming" (paper §7).  Labeled datasets are registered with
    instrument/sample metadata; ``augment_for`` returns prior labeled
    datasets matching the new experiment so (re)training can start from a
    larger corpus or skip labeling entirely.
    """

    def __init__(self) -> None:
        self._datasets: Dict[str, List] = {}

    def register(self, experiment_class: str, artifact: FileRef,
                 metadata: Optional[Dict[str, Any]] = None,
                 labeled: bool = True):
        entry = {
            "artifact": artifact,
            "metadata": dict(metadata or {}),
            "labeled": labeled,
            "version": len(self._datasets.get(experiment_class, [])) + 1,
        }
        self._datasets.setdefault(experiment_class, []).append(entry)
        return entry

    def augment_for(self, experiment_class: str, *,
                    labeled_only: bool = True,
                    match: Optional[Dict[str, Any]] = None) -> List:
        out = []
        for e in self._datasets.get(experiment_class, []):
            if labeled_only and not e["labeled"]:
                continue
            if match and any(e["metadata"].get(k) != v
                             for k, v in match.items()):
                continue
            out.append(e)
        return out

    def total_bytes(self, experiment_class: str) -> int:
        return sum(e["artifact"].nbytes
                   for e in self._datasets.get(experiment_class, []))
