"""Facility topology: experiment/edge facilities, data centers, WAN links.

Mirrors the paper's SLAC <-> ALCF deployment (§5.1): a 100 Gbps ESnet
backbone with ~48 ms RTT, 10 Gbps DTN NICs on each side, an edge facility
hosting edge-AI devices, and a data center hosting DCAI systems (Cerebras /
SambaNova / multi-GPU in the paper; the TPU-pod mesh here).

The topology is data, not behaviour — the transfer and compute services read
link/device parameters from it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class WanLink:
    """Directed WAN link.  Rates in bytes/second, rtt in seconds."""

    src: str
    dst: str
    backbone_bps: float          # optical backbone capacity
    nic_bps: float               # DTN NIC capacity (the practical ceiling)
    rtt: float                   # round-trip time
    per_file_startup: float      # the paper's "S" constant (per file)

    def effective_rate(self, concurrency: int = 4) -> float:
        """Fig.-3-shaped throughput: rises with transfer concurrency and
        saturates at the DTN NIC ceiling (the paper measured >1 GB/s with
        multiple concurrent files on a 10 Gbps NIC)."""
        c = max(1, concurrency)
        single_stream = self.nic_bps * 0.35        # one stream ~35% of NIC
        return min(self.nic_bps * 0.92, single_stream * c)


@dataclasses.dataclass(frozen=True)
class ComputeDevice:
    """A compute resource at a facility.

    kind: "edge_ai" | "local_gpu" | "dcai" | "cpu_cluster"
    peak_flops: effective sustained FLOP/s for DNN training (bf16/fp32 mix)
    """

    name: str
    facility: str
    kind: str
    peak_flops: float
    hbm_bw: float = 0.0
    n_chips: int = 1
    queue_wait: float = 0.0       # mean scheduler/queue latency (s)
    service_overhead: float = 0.0  # per-invocation service overhead (s)


@dataclasses.dataclass
class Facility:
    name: str
    devices: Dict[str, ComputeDevice] = dataclasses.field(default_factory=dict)

    def add(self, dev: ComputeDevice) -> None:
        self.devices[dev.name] = dev


class Topology:
    def __init__(self) -> None:
        self.facilities: Dict[str, Facility] = {}
        self.links: Dict[Tuple[str, str], WanLink] = {}

    def add_facility(self, fac: Facility) -> None:
        self.facilities[fac.name] = fac

    def add_link(self, link: WanLink) -> None:
        self.links[(link.src, link.dst)] = link

    def link(self, src: str, dst: str) -> WanLink:
        if src == dst:
            # intra-facility: effectively free (local filesystem / LAN)
            return WanLink(src, dst, 1e12, 1e11, 1e-4, 1e-3)
        return self.links[(src, dst)]

    def device(self, name: str) -> ComputeDevice:
        for fac in self.facilities.values():
            if name in fac.devices:
                return fac.devices[name]
        raise KeyError(name)


# ---------------------------------------------------------------------------
# The paper's deployment, with the TPU-pod DCAI added as this repo's target.
# Constants from §4.2/§5.1: 100 Gbps backbone, 10 Gbps DTN NIC, 48 ms RTT,
# ~1 GB/s sustained Globus throughput, Cerebras trains BraggNN in 19 s.
# ---------------------------------------------------------------------------
def paper_topology() -> Topology:
    topo = Topology()

    edge = Facility("slac")
    edge.add(ComputeDevice("edge-tpu", "slac", "edge_ai", peak_flops=4e12,
                           service_overhead=0.1))
    edge.add(ComputeDevice("local-v100", "slac", "local_gpu",
                           peak_flops=14e12, hbm_bw=0.9e12,
                           service_overhead=0.1))
    topo.add_facility(edge)

    dc = Facility("alcf")
    dc.add(ComputeDevice("cerebras", "alcf", "dcai", peak_flops=2.5e15,
                         n_chips=1, queue_wait=2.0, service_overhead=1.0))
    dc.add(ComputeDevice("sambanova-1rdu", "alcf", "dcai", peak_flops=3e14,
                         n_chips=1, queue_wait=2.0, service_overhead=1.0))
    dc.add(ComputeDevice("gpu-server-8xv100", "alcf", "dcai",
                         peak_flops=8 * 14e12, n_chips=8, queue_wait=2.0,
                         service_overhead=1.0))
    # this repo's target DCAI: TPU v5e pod (197 TFLOP/s bf16 per chip)
    dc.add(ComputeDevice("tpu-v5e-pod", "alcf", "dcai",
                         peak_flops=256 * 197e12, hbm_bw=256 * 819e9,
                         n_chips=256, queue_wait=2.0, service_overhead=1.0))
    dc.add(ComputeDevice("cpu-cluster-1024", "alcf", "cpu_cluster",
                         peak_flops=1024 * 5e10, n_chips=1024,
                         queue_wait=2.0, service_overhead=1.0))
    topo.add_facility(dc)

    # 100 Gbps backbone = 12.5 GB/s; 10 Gbps DTN NIC = 1.25 GB/s
    topo.add_link(WanLink("slac", "alcf", backbone_bps=12.5e9,
                          nic_bps=1.25e9, rtt=0.048, per_file_startup=0.6))
    topo.add_link(WanLink("alcf", "slac", backbone_bps=12.5e9,
                          nic_bps=1.25e9, rtt=0.048, per_file_startup=0.6))
    return topo
