"""Paper future-work #3, implemented: overlap A (labeling) and T (training).

"As the training process is mini-batch based which can be started before
getting all training samples, we can try to partially overlap A and T in
the workflow to shorten end-to-end time." (paper §7)

``run_overlapped_label_train`` executes labeling and training as a software
pipeline over micro-shards: shard i is labeled while shard i-1 trains.
Compute is real (both stages actually run); the clock charges the pipeline's
critical path per stage — max(label_i, train_{i-1}) — rather than the sum,
which is exactly the paper's proposed saving.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

from repro.core.simclock import SimClock
from repro.core.system import System
from repro.core.transfer import FileRef


def run_overlapped_label_train(
        sys_: System, *, dataset_facility: str, dataset_name: str,
        label_fn: Callable, train_init_fn: Callable,
        train_shard_fn: Callable, n_shards: int = 8,
        artifact_name: str = "model.npz",
        artifact_bytes: int = 3_000_000) -> Dict:
    """Pipeline: [label s0][label s1 | train s0][label s2 | train s1]...

    label_fn(raw_shard) -> labels;  train_init_fn() -> state;
    train_shard_fn(state, shard, labels) -> (state, metrics).
    Returns {"state", "per_stage", "serial_s", "pipelined_s", "saving_s"}.
    """
    clock = sys_.clock
    raw = sys_.store.get(dataset_facility, dataset_name).payload
    n = raw["patches"].shape[0]
    per = n // n_shards
    shards = [
        {k: v[i * per:(i + 1) * per] for k, v in raw.items()}
        for i in range(n_shards)
    ]

    state = train_init_fn()
    label_times: List[float] = []
    train_times: List[float] = []
    labeled: List = []
    metrics = None

    serial = 0.0
    pipelined = 0.0
    for stage in range(n_shards + 1):
        t_label = 0.0
        t_train = 0.0
        if stage < n_shards:
            t0 = time.perf_counter()
            labeled.append(label_fn(shards[stage]))
            t_label = time.perf_counter() - t0
            label_times.append(t_label)
        if stage > 0:
            t0 = time.perf_counter()
            state, metrics = train_shard_fn(state, shards[stage - 1],
                                            labeled[stage - 1])
            t_train = time.perf_counter() - t0
            train_times.append(t_train)
        # the two stages run on different resources (CPU labeling cluster vs
        # the DCAI accelerator): the pipeline's critical path is the max
        serial += t_label + t_train
        stage_t = max(t_label, t_train)
        pipelined += stage_t
        clock.advance(stage_t, f"A||T stage {stage}", "real")

    sys_.store.put("alcf", FileRef(artifact_name, artifact_bytes,
                                   payload=state))
    return {
        "state": state,
        "metrics": metrics,
        "serial_s": serial,
        "pipelined_s": pipelined,
        "saving_s": serial - pipelined,
        "label_times": label_times,
        "train_times": train_times,
    }
