"""Globus-Auth-like identity/scope layer (paper §3: "Globus Auth is used to
authenticate all interactions with Action Providers, Actions and Flows").

In-process stand-in with real semantics: tokens carry scopes; providers
declare a required scope; the flow engine validates the token before every
action invocation and fails the action (not the whole service) on a scope
mismatch — mirroring how a mis-scoped Globus token behaves.
"""
from __future__ import annotations

import dataclasses
import uuid
from typing import FrozenSet, Iterable


class AuthError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class Token:
    subject: str
    scopes: FrozenSet[str]
    token_id: str

    def require(self, scope: str) -> None:
        if scope not in self.scopes:
            raise AuthError(
                f"token for {self.subject!r} lacks scope {scope!r}")


class AuthService:
    """Issues and validates tokens."""

    def __init__(self) -> None:
        self._issued: dict = {}

    def issue(self, subject: str, scopes: Iterable[str]) -> Token:
        tok = Token(subject, frozenset(scopes), uuid.uuid4().hex)
        self._issued[tok.token_id] = tok
        return tok

    def validate(self, token: Token) -> None:
        if token.token_id not in self._issued:
            raise AuthError("unknown token")


SCOPE_TRANSFER = "urn:repro:transfer"
SCOPE_COMPUTE = "urn:repro:compute"
SCOPE_FLOWS = "urn:repro:flows"
