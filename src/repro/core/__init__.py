"""The paper's primary contribution: the geographically distributed
workflow system (flows + funcX + transfer + cost model + sim clock)."""
from repro.core.system import System, build_system, dnn_trainer_flow  # noqa: F401
from repro.core.simclock import SimClock  # noqa: F401
from repro.core.costmodel import CostModel, OperationCosts  # noqa: F401
