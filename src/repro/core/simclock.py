"""Hybrid simulation clock.

The paper's evaluation metric is *end-to-end turnaround time* across a
geographically distributed workflow.  On this single-host container the
compute steps run for real (measured wall time) while the WAN/service costs
are simulated (the paper's own linear transfer model and measured service
overheads).  ``SimClock`` fuses the two:

  * ``advance(dt)``      — add simulated seconds (transfer, queueing, RTT);
  * ``measure()``        — context manager measuring real wall time of a
                           compute step and adding it to the clock;
  * ``charge(dt)``       — add *modeled* compute seconds (e.g. DCAI training
                           time derived from the roofline model) without
                           running anything for that long.

Every addition is tagged so benchmarks can decompose turnaround into
(real compute / modeled compute / simulated WAN+service) — EXPERIMENTS.md
reports these separately.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Iterator, List, Tuple


@dataclasses.dataclass
class ClockEntry:
    kind: str        # "real" | "modeled" | "sim"
    label: str
    seconds: float
    at: float        # sim timestamp when the entry started


class SimClock:
    """Virtual clock fusing real, modeled, and simulated seconds."""

    def __init__(self) -> None:
        self._now = 0.0
        self.log: List[ClockEntry] = []
        # perf_counter stamps of open measure() blocks (outermost first):
        # while one is open, `now` runs live so latency marks stamped
        # mid-step (t_first_token) land inside the step, not at its start
        self._live: List[float] = []

    @property
    def now(self) -> float:
        """Current sim time; advances live inside an open measure()."""
        if self._live:
            return self._now + (time.perf_counter() - self._live[0])
        return self._now

    def advance(self, seconds: float, label: str = "", kind: str = "sim"
                ) -> None:
        assert seconds >= 0, (label, seconds)
        self.log.append(ClockEntry(kind, label, seconds, self._now))
        self._now += seconds

    def charge(self, seconds: float, label: str = "") -> None:
        self.advance(seconds, label, kind="modeled")

    @contextlib.contextmanager
    def measure(self, label: str = "") -> Iterator[None]:
        """Measure a real compute step: wall time accrues to the clock
        (live through ``now`` while the block is open, committed to
        ``_now`` when the outermost block exits)."""
        t0 = time.perf_counter()
        start = self.now
        self._live.append(t0)
        try:
            yield
        finally:
            self._live.pop()
            dt = time.perf_counter() - t0
            self.log.append(ClockEntry("real", label, dt, start))
            if not self._live:
                self._now += dt

    # ------------------------------------------------------------------
    def breakdown(self) -> Dict[str, float]:
        out: Dict[str, float] = {"real": 0.0, "modeled": 0.0, "sim": 0.0}
        for e in self.log:
            out[e.kind] += e.seconds
        out["total"] = self._now
        return out

    def timeline(self) -> List[Tuple[float, str, str, float]]:
        return [(e.at, e.kind, e.label, e.seconds) for e in self.log]
