"""Checkpointing: pytree save/restore with manifest + integrity checks.

No tensorstore/orbax dependency — flat .npz per checkpoint with a JSON
manifest mapping tree paths to array entries, dtype/shape recorded and
verified on restore, plus a crc32 over the packed bytes.  Supports async
best-k retention like a production trainer would.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any

# extension dtypes stored as bit-equivalent integer views (npz can't
# round-trip ml_dtypes arrays)
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
}


def _to_storable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    for name, (ext, view) in _EXT_DTYPES.items():
        if arr.dtype == ext:
            return arr.view(view), name
    return arr, str(arr.dtype)


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        ext, view = _EXT_DTYPES[dtype_name]
        return arr.view(ext)
    return arr


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat.append((key, np.asarray(leaf)))
    return flat


def save_checkpoint(directory: str, step: int, tree: PyTree, *,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = [(k, *_to_storable(a)) for k, a in _flatten_with_paths(tree)]
    arrays = {f"a{i}": arr for i, (_k, arr, _d) in enumerate(flat)}
    manifest = {
        "step": step,
        "entries": [
            {"path": k, "array": f"a{i}", "dtype": d,
             "shape": list(a.shape),
             "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes())}
            for i, (k, a, d) in enumerate(flat)
        ],
        "extra": extra or {},
    }
    base = os.path.join(directory, f"ckpt_{step:08d}")
    np.savez(base + ".npz", **arrays)
    with open(base + ".json", "w") as f:
        json.dump(manifest, f)
    _retain(directory, keep)
    return base


def _retain(directory: str, keep: int) -> None:
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".json"))
    for old in ckpts[:-keep]:
        step_tag = old[:-5]
        for suffix in (".json", ".npz"):
            p = os.path.join(directory, step_tag + suffix)
            if os.path.exists(p):
                os.remove(p)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".json")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``template`` (shape/dtype verified)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    base = os.path.join(directory, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        manifest = json.load(f)
    data = np.load(base + ".npz")
    by_path = {e["path"]: e for e in manifest["entries"]}

    flat_t = _flatten_with_paths(template)
    leaves = []
    for key, tmpl in flat_t:
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        e = by_path[key]
        arr = data[e["array"]]
        if list(arr.shape) != list(tmpl.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs "
                f"template {tmpl.shape}")
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != e["crc32"]:
            raise IOError(f"crc mismatch at {key} (corrupt checkpoint)")
        arr = _from_storable(arr, e["dtype"])
        leaves.append(arr.astype(tmpl.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, manifest


def tree_nbytes(tree: PyTree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))
