"""Training loop: jit'd train step with grad accumulation, mixed precision,
metrics, and checkpointing.  Mesh-aware: the same ``make_train_step`` is used
by CPU smoke tests (no mesh) and by the production launcher (pjit shardings
injected by launch/train.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, global_norm
from repro.train import checkpoint as ckpt_lib

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


def make_train_step(loss_fn: Callable, optimizer: Optimizer, *,
                    grad_accum: int = 1,
                    donate: bool = True) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict).

    Returns jit'd step(params, opt_state, batch) ->
    (params, opt_state, metrics).  With grad_accum > 1, batch's leading axis
    must be (grad_accum * local_batch) and is split into microbatches inside
    a scan (constant memory in accumulation length).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return grads, metrics

    def step_fn(params, opt_state, batch):
        if grad_accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            _, m_shape = jax.eval_shape(
                grads_of, params, jax.tree.map(lambda x: x[0], micro))
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m / grad_accum, metrics)

        metrics["grad_norm"] = global_norm(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, metrics

    donate_args = (0, 1) if donate else ()
    return jax.jit(step_fn, donate_argnums=donate_args)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = only final
    ckpt_dir: Optional[str] = None
    grad_accum: int = 1


def fit(loss_fn: Callable, optimizer: Optimizer, params: PyTree,
        data_iter, cfg: TrainerConfig,
        *, callbacks=()) -> Tuple[TrainState, Dict[str, list]]:
    """Run the loop; returns final state + metric history."""
    step_fn = make_train_step(loss_fn, optimizer, grad_accum=cfg.grad_accum)
    opt_state = optimizer.init(params)
    history: Dict[str, list] = {"loss": [], "step_time": []}
    t_wall = time.perf_counter()

    for step in range(1, cfg.steps + 1):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step == 1 or step % cfg.log_every == 0 or step == cfg.steps:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            history["loss"].append((step, loss))
            history["step_time"].append((step, dt))
            for cb in callbacks:
                cb(step, metrics)
        if (cfg.ckpt_dir and cfg.ckpt_every
                and step % cfg.ckpt_every == 0):
            ckpt_lib.save_checkpoint(cfg.ckpt_dir, step,
                                     {"params": params,
                                      "opt_state": opt_state})

    if cfg.ckpt_dir:
        ckpt_lib.save_checkpoint(cfg.ckpt_dir, cfg.steps,
                                 {"params": params, "opt_state": opt_state})
    history["wall_time"] = time.perf_counter() - t_wall
    return TrainState(params, opt_state, cfg.steps), history
