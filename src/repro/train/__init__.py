from repro.train.trainer import (TrainerConfig, TrainState, fit,  # noqa: F401
                                 make_train_step)
from repro.train import checkpoint  # noqa: F401
