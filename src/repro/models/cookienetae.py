"""CookieNetAE — 16-channel eToF energy-pdf estimator (paper §5.2).

8 convolution layers, ReLU everywhere, MSE loss, Adam lr=1e-3.  Input: one
image (16 channels x 128 energy bins) of per-channel empirical histograms;
output: the energy-angle probability density per channel (same shape,
softmax-normalized along the energy axis).

The paper states 343,937 trainable parameters.  The reference's exact layer
widths are not public; this port uses an 8-conv encoder-decoder stack
1->32->64->128->128->64->32->16->1 (1x1 head) totalling 337,153 params —
within 2% of the paper's count (asserted by tests/test_paper_models.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import CookieNetAEConfig
from repro.models.common import split_keys


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout),
                                        jnp.float32) / fan_in ** 0.5)


def _conv(x, w, b):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b


_STACK = [
    # (kernel, cin, cout)
    (3, 1, 32),
    (3, 32, 64),
    (3, 64, 128),
    (3, 128, 128),
    (3, 128, 64),
    (3, 64, 32),
    (3, 32, 16),
    (1, 16, 1),
]


def init_params(key, cfg: CookieNetAEConfig) -> Dict:
    ks = split_keys(key, len(_STACK))
    p = {}
    for i, (k, cin, cout) in enumerate(_STACK):
        p[f"conv{i}_w"] = _conv_init(ks[i], k, k, cin, cout)
        p[f"conv{i}_b"] = jnp.zeros((cout,))
    return p


def forward(params: Dict, x: jax.Array, cfg: CookieNetAEConfig) -> jax.Array:
    """x: (B, 16, 128, 1) histograms -> (B, 16, 128, 1) energy pdf."""
    h = x
    for i in range(len(_STACK)):
        h = _conv(h, params[f"conv{i}_w"], params[f"conv{i}_b"])
        if i < len(_STACK) - 1:
            h = jax.nn.relu(h)
    # probability density along the energy-bin axis
    return jax.nn.softmax(h, axis=2)


def loss_fn(params: Dict, batch: Dict, cfg: CookieNetAEConfig) -> Tuple:
    pred = forward(params, batch["images"], cfg)
    mse = jnp.mean((pred - batch["targets"]) ** 2)
    return mse, {"mse": mse}
