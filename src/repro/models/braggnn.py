"""BraggNN [arXiv:2008.08198] — Bragg-peak localization from 11x11 patches.

Faithful JAX port of the public reference (github.com/lzhengchun/BraggNN):
  * conv 3x3 (valid) -> 64 channels on the 11x11 patch,
  * a non-local self-attention block over the 9x9 feature map,
  * conv stack 64 -> 32 -> 8 (3x3 valid),
  * FC stack (fcsz = 16, 8, 4, 2) -> (y, x) sub-pixel peak center.
All convs/FCs use leaky-relu as in the reference.  ~45K parameters — the
paper's point is precisely that such edge models retrain in seconds on a
DCAI system.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import BraggNNConfig
from repro.models.common import dense_init, split_keys


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout),
                                        jnp.float32) / fan_in ** 0.5)


def _conv(x, w, b=None, padding="VALID"):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b
    return y


def init_params(key, cfg: BraggNNConfig) -> Dict:
    c = cfg.base_channels
    ks = split_keys(key, 12)
    p: Dict = {
        "conv1_w": _conv_init(ks[0], 3, 3, 1, c),
        "conv1_b": jnp.zeros((c,)),
        # non-local attention block (1x1 convs: theta, phi, g, out)
        "nl_theta": _conv_init(ks[1], 1, 1, c, c // 2),
        "nl_phi": _conv_init(ks[2], 1, 1, c, c // 2),
        "nl_g": _conv_init(ks[3], 1, 1, c, c // 2),
        "nl_out": _conv_init(ks[4], 1, 1, c // 2, c),
        "conv2_w": _conv_init(ks[5], 3, 3, c, c // 2),
        "conv2_b": jnp.zeros((c // 2,)),
        "conv3_w": _conv_init(ks[6], 3, 3, c // 2, 8),
        "conv3_b": jnp.zeros((8,)),
    }
    # feature map after three VALID 3x3 convs on 11x11: 9 -> 7 -> 5
    flat = 5 * 5 * 8
    sizes = (flat,) + cfg.fcsz
    for i in range(len(cfg.fcsz)):
        p[f"fc{i}_w"] = dense_init(ks[7 + i], (sizes[i], sizes[i + 1]))
        p[f"fc{i}_b"] = jnp.zeros((sizes[i + 1],))
    return p


def forward(params: Dict, x: jax.Array, cfg: BraggNNConfig) -> jax.Array:
    """x: (B, 11, 11, 1) normalized patches -> (B, 2) peak centers in [0,1]."""
    lrelu = lambda v: jax.nn.leaky_relu(v, 0.01)
    h = lrelu(_conv(x, params["conv1_w"], params["conv1_b"]))   # (B,9,9,64)

    # non-local self-attention over spatial positions
    B, H, W, C = h.shape
    theta = _conv(h, params["nl_theta"]).reshape(B, H * W, -1)
    phi = _conv(h, params["nl_phi"]).reshape(B, H * W, -1)
    g = _conv(h, params["nl_g"]).reshape(B, H * W, -1)
    attn = jax.nn.softmax(
        jnp.einsum("bqc,bkc->bqk", theta, phi) / (theta.shape[-1] ** 0.5),
        axis=-1)
    nl = jnp.einsum("bqk,bkc->bqc", attn, g).reshape(B, H, W, -1)
    h = h + _conv(nl, params["nl_out"])

    h = lrelu(_conv(h, params["conv2_w"], params["conv2_b"]))   # (B,7,7,32)
    h = lrelu(_conv(h, params["conv3_w"], params["conv3_b"]))   # (B,5,5,8)
    h = h.reshape(B, -1)
    n_fc = len(cfg.fcsz)
    for i in range(n_fc):
        h = h @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_fc - 1:
            h = lrelu(h)
    return jax.nn.sigmoid(h)      # peak center normalized to the patch


def loss_fn(params: Dict, batch: Dict, cfg: BraggNNConfig) -> Tuple:
    pred = forward(params, batch["patches"], cfg)
    mse = jnp.mean((pred - batch["centers"]) ** 2)
    return mse, {"mse": mse}
