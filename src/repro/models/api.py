"""Unified model API — one interface over all architecture families.

``build_model(cfg)`` returns a :class:`ModelAPI` with:
  * ``init(key)``                         -> params
  * ``loss(params, batch)``               -> (loss, metrics)      [train]
  * ``forward(params, tokens, ...)``      -> (logits, aux)        [prefill]
  * ``init_cache(batch, cache_len)``      -> cache/state
  * ``decode_step(params, cache, tok)``   -> (logits, new cache)  [serve]
  * ``effective_window(seq_len)``         -> attention window for a shape
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, recurrent, transformer, vlm

PyTree = Any

# full-attention archs switch to their long-context SWA variant above this
LONG_CONTEXT_THRESHOLD = 65_536


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[[jax.Array], PyTree]
    loss: Callable[..., Tuple[jax.Array, Dict]]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]
    init_cache: Callable[..., PyTree]
    decode_step: Callable[..., Tuple[jax.Array, PyTree]]
    # paged-KV serving path (block pool + block tables); None for families
    # whose decode state is O(1) recurrent rather than a growing KV sequence.
    # ``paged_step`` is the unified chunked step — (B, C>=1) tokens per call,
    # prefill chunks and single-token decode share one compiled path;
    # ``paged_decode_step`` is its q_len = 1 compatibility alias.
    init_paged_cache: Optional[Callable[..., PyTree]] = None
    paged_step: Optional[Callable[..., Tuple[jax.Array, PyTree]]] = None
    paged_decode_step: Optional[Callable[..., Tuple[jax.Array, PyTree]]] = None
    # ``ragged_step`` consumes one flat (T,) stream of all scheduled tokens
    # (mixed prefill chunks + decodes, per-token lane/pos/slot metadata in
    # the cache) — the serving layout that kills the rectangular padding
    # tax.  When the engine also ships ``tile_meta``/``row_tile`` (a
    # serving.batch.TileMap, the default) the attention read runs the
    # segment-tiled grid — KV blocks swept once per q-tile, not per token;
    # the static ``tile`` width rides through **kw into the jitted step.
    #
    # Verification-logits contract (speculative decode): both multi-token
    # steps return logits for EVERY position of every segment — (B, C, V)
    # from ``paged_step``, (T, V) from ``ragged_step`` — not just each
    # lane's last row.  Row j of a segment is the next-token distribution
    # given the segment's tokens 0..j, so the engine can verify a chain of
    # drafted tokens against the model's own argmax in one step.  A step
    # implementation that only materialized final rows would silently
    # break ``PagedDecodeEngine(spec=True)``.
    ragged_step: Optional[Callable[..., Tuple[jax.Array, PyTree]]] = None

    @property
    def supports_paged(self) -> bool:
        # a pre-unification ModelAPI carrying only the q_len=1 step still
        # counts (resolve_paged_step wraps it for the engine)
        return (self.paged_step is not None
                or self.paged_decode_step is not None)

    @property
    def supports_ragged(self) -> bool:
        return self.ragged_step is not None

    @property
    def supports_spec(self) -> bool:
        """Speculative decode needs a true multi-token step (q_len >= 1
        with per-position logits); the q_len=1 legacy step cannot verify
        draft chains."""
        return self.paged_step is not None

    def resolve_paged_step(self):
        """The unified chunked step, or the q_len=1 legacy step when that
        is all the family provides (correct for width-1 calls only — the
        engine clamps chunk_tokens to 1 in that case)."""
        return self.paged_step or self.paged_decode_step

    def effective_window(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window:
            return cfg.sliding_window
        if cfg.long_context_window and seq_len > LONG_CONTEXT_THRESHOLD:
            return cfg.long_context_window
        return 0


def build_model(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family

    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: recurrent.init_zamba_params(key, cfg),
            loss=_lm_loss_wrapper(recurrent.zamba_forward, cfg),
            forward=lambda p, t, **kw: recurrent.zamba_forward(p, t, cfg, **kw),
            init_cache=lambda b, n, **kw: recurrent.init_zamba_cache(
                cfg, b, n, **kw),
            decode_step=lambda p, c, t, **kw: recurrent.zamba_decode_step(
                p, c, t, cfg, **kw),
        )
    if fam == "ssm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: recurrent.init_xlstm_params(key, cfg),
            loss=_lm_loss_wrapper(recurrent.xlstm_forward, cfg),
            forward=lambda p, t, **kw: recurrent.xlstm_forward(p, t, cfg, **kw),
            init_cache=lambda b, n, **kw: recurrent.init_xlstm_cache(
                cfg, b, n, **kw),
            decode_step=lambda p, c, t, **kw: recurrent.xlstm_decode_step(
                p, c, t, cfg, **kw),
        )
    if fam == "audio":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            loss=lambda p, b, **kw: encdec.loss_fn(p, b, cfg, **kw),
            forward=lambda p, t, **kw: _encdec_forward(p, t, cfg, **kw),
            init_cache=lambda b, n, **kw: encdec.init_cache(cfg, b, n, **kw),
            decode_step=lambda p, c, t, **kw: encdec.decode_step(
                p, c, t, cfg, **kw),
        )
    if fam == "vlm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: vlm.init_params(key, cfg),
            loss=lambda p, b, **kw: vlm.loss_fn(p, b, cfg, **kw),
            forward=lambda p, t, **kw: vlm.forward(p, t, cfg, **kw),
            init_cache=lambda b, n, **kw: vlm.init_cache(cfg, b, n, **kw),
            decode_step=lambda p, c, t, **kw: vlm.decode_step(
                p, c, t, cfg, **kw),
            init_paged_cache=lambda b, **kw: vlm.init_paged_cache(
                cfg, b, **kw),
            paged_step=lambda p, c, t, **kw: vlm.paged_step(
                p, c, t, cfg, **kw),
            paged_decode_step=lambda p, c, t, **kw: vlm.paged_decode_step(
                p, c, t, cfg, **kw),
            ragged_step=lambda p, c, t, **kw: vlm.ragged_step(
                p, c, t, cfg, **kw),
        )
    # dense / moe
    return ModelAPI(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        loss=lambda p, b, **kw: transformer.loss_fn(p, b, cfg, **kw),
        forward=lambda p, t, **kw: transformer.forward(p, t, cfg, **kw),
        init_cache=lambda b, n, **kw: transformer.init_cache(cfg, b, n, **kw),
        decode_step=lambda p, c, t, **kw: transformer.decode_step(
            p, c, t, cfg, **kw),
        init_paged_cache=lambda b, **kw: transformer.init_paged_cache(
            cfg, b, **kw),
        paged_step=lambda p, c, t, **kw: transformer.paged_step(
            p, c, t, cfg, **kw),
        paged_decode_step=lambda p, c, t, **kw: transformer.paged_decode_step(
            p, c, t, cfg, **kw),
        ragged_step=lambda p, c, t, **kw: transformer.ragged_step(
            p, c, t, cfg, **kw),
    )


def _lm_loss_wrapper(forward_fn, cfg: ArchConfig):
    def loss(params, batch, *, window: int = 0, attn_chunk: int = 512,
             remat: bool = True):
        logits, aux = forward_fn(params, batch["tokens"], cfg, window=window,
                                 attn_chunk=attn_chunk, remat=remat)
        return transformer.lm_loss(logits, batch["labels"], aux, 0.0)

    return loss


def _encdec_forward(params, tokens, cfg, *, frames=None, window: int = 0,
                    attn_chunk: int = 512, remat: bool = True, **kw):
    enc = encdec.encode(params, frames, cfg)
    logits = encdec.decode_train(params, tokens, enc, cfg,
                                 attn_chunk=attn_chunk, remat=remat)
    return logits, jnp.zeros((), jnp.float32)
