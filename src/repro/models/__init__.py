from repro.models.api import ModelAPI, build_model  # noqa: F401
