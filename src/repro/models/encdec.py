"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB (assignment carve-out): the
model consumes precomputed frame embeddings ``frames (B, 1500, d_model)``.
Everything downstream — sinusoidal encoder positions, bidirectional encoder,
learned decoder positions (clamped at max_decoder_positions-1 for structural
lowering of longer assigned shapes), causal self-attention + cross-attention
decoder, tied LM head — is real.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.common import apply_norm, embed_init, norm_params, split_keys

PyTree = Any


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
def _enc_block_params(key, cfg: ArchConfig) -> Dict:
    k1, k2 = split_keys(key, 2)
    return {
        "attn_norm": norm_params(cfg.norm_type, cfg.d_model),
        "attn": layers.attention_params(k1, cfg),
        "mlp_norm": norm_params(cfg.norm_type, cfg.d_model),
        "mlp": layers.mlp_params(k2, cfg),
    }


def _dec_block_params(key, cfg: ArchConfig) -> Dict:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "self_norm": norm_params(cfg.norm_type, cfg.d_model),
        "self_attn": layers.attention_params(k1, cfg),
        "cross_norm": norm_params(cfg.norm_type, cfg.d_model),
        "cross_attn": layers.attention_params(k2, cfg),
        "mlp_norm": norm_params(cfg.norm_type, cfg.d_model),
        "mlp": layers.mlp_params(k3, cfg),
    }


def init_params(key, cfg: ArchConfig) -> Dict:
    keys = split_keys(key, 4 + cfg.n_encoder_layers + cfg.n_layers)
    p = {
        "embed": layers.embedding_params(keys[0], cfg.vocab_size, cfg.d_model),
        "dec_pos": embed_init(keys[1], (cfg.max_decoder_positions,
                                        cfg.d_model)),
        # frontend-stub projection: frame embeds -> d_model (real, learned)
        "frame_proj": embed_init(keys[2], (cfg.frontend.d_embed, cfg.d_model))
        if cfg.frontend else None,
        "enc_final_norm": norm_params(cfg.norm_type, cfg.d_model),
        "dec_final_norm": norm_params(cfg.norm_type, cfg.d_model),
        "enc_blocks": _stack([
            _enc_block_params(keys[3 + i], cfg)
            for i in range(cfg.n_encoder_layers)
        ]),
        "dec_blocks": _stack([
            _dec_block_params(keys[3 + cfg.n_encoder_layers + i], cfg)
            for i in range(cfg.n_layers)
        ]),
    }
    return p


# ---------------------------------------------------------------------------
def encode(params: Dict, frames: jax.Array, cfg: ArchConfig,
           compute_dtype=jnp.bfloat16, remat: bool = True) -> jax.Array:
    """frames (B, T_enc, d_embed) -> (B, T_enc, d_model)."""
    x = frames.astype(compute_dtype)
    if params.get("frame_proj") is not None:
        x = x @ params["frame_proj"].astype(compute_dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(compute_dtype)

    def block_body(bp, x):
        xn = apply_norm(cfg.norm_type, bp["attn_norm"], x)
        # bidirectional: reuse full_attention without causal mask
        q, k, v = layers.project_qkv(bp["attn"], xn,
                                     jnp.arange(x.shape[1]), cfg)
        a = layers.full_attention(q, k, v, causal=False)
        x = x + layers.project_out(bp["attn"], a, cfg)
        xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
        return x + layers.apply_mlp(bp["mlp"], xm, cfg)

    if remat:
        # §Perf-3 iter 2: without this the 1500^2 bidirectional attention
        # probabilities of every encoder layer are saved for backward
        block_body = jax.checkpoint(block_body)

    def block(x, bp):
        return block_body(bp, x), None

    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return apply_norm(cfg.norm_type, params["enc_final_norm"], x)


def _dec_positions(cfg: ArchConfig, positions: jax.Array) -> jax.Array:
    return jnp.minimum(positions, cfg.max_decoder_positions - 1)


def _cross_attention(bp: Dict, x, enc_kv, cfg):
    xn = apply_norm(cfg.norm_type, bp["cross_norm"], x)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", xn, bp["cross_attn"]["wq"].astype(dt))
    if cfg.use_bias:
        q = q + bp["cross_attn"]["bq"].astype(dt)
    a = layers.full_attention(q, enc_kv["k"], enc_kv["v"], causal=False)
    return x + layers.project_out(bp["cross_attn"], a, cfg)


def encoder_kv(params: Dict, enc_out: jax.Array, cfg: ArchConfig) -> Dict:
    """Precompute per-layer cross-attention K/V from encoder output."""
    def one(bp):
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wv"].astype(dt))
        if cfg.use_bias:
            k = k + bp["cross_attn"]["bk"].astype(dt)
            v = v + bp["cross_attn"]["bv"].astype(dt)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["dec_blocks"])   # leaves: (L, B, T_enc, ...)


def decode_train(params: Dict, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ArchConfig, *, attn_chunk: int = 512,
                 remat: bool = True) -> jax.Array:
    """Teacher-forced decoder.  tokens (B, S) -> logits (B, S, V)."""
    dt = enc_out.dtype
    x = layers.embed_tokens(params["embed"], tokens, dt)
    pos = _dec_positions(cfg, jnp.arange(tokens.shape[1]))
    x = x + params["dec_pos"].astype(dt)[pos]
    cross = encoder_kv(params, enc_out, cfg)

    def block(x, inp):
        bp, ckv = inp

        def inner(x_):
            xn = apply_norm(cfg.norm_type, bp["self_norm"], x_)
            q, k, v = layers.project_qkv(bp["self_attn"], xn,
                                         jnp.arange(x_.shape[1]), cfg)
            a = layers.causal_attention(q, k, v, chunk=attn_chunk)
            h = x_ + layers.project_out(bp["self_attn"], a, cfg)
            h = _cross_attention(bp, h, ckv, cfg)
            hm = apply_norm(cfg.norm_type, bp["mlp_norm"], h)
            return h + layers.apply_mlp(bp["mlp"], hm, cfg)

        if remat:
            inner = jax.checkpoint(inner)
        return inner(x), None

    x, _ = jax.lax.scan(block, x, (params["dec_blocks"], cross))
    x = apply_norm(cfg.norm_type, params["dec_final_norm"], x)
    return layers.lm_logits(None, params["embed"], x, True)


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *,
            window: int = 0, attn_chunk: int = 512,
            remat: bool = True) -> Tuple[jax.Array, Dict]:
    del window
    enc = encode(params, batch["frames"], cfg)
    logits = decode_train(params, batch["tokens"], enc, cfg,
                          attn_chunk=attn_chunk, remat=remat)
    from repro.models.transformer import lm_loss
    return lm_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Dict:
    del window
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((L, batch, cache_len, Hkv, D), dtype),
            "v": jnp.zeros((L, batch, cache_len, Hkv, D), dtype),
        },
        # cross K/V computed once at request admission (prefill)
        "cross": {
            "k": jnp.zeros((L, batch, cfg.encoder_positions, Hkv, D), dtype),
            "v": jnp.zeros((L, batch, cfg.encoder_positions, Hkv, D), dtype),
        },
        "slot_positions": -jnp.ones((batch, cache_len), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ArchConfig, *, window: int = 0,
                compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    del window
    B = tokens.shape[0]
    pos = cache["pos"]
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)
    x = x + params["dec_pos"].astype(compute_dtype)[
        _dec_positions(cfg, pos)][:, None]

    n_slots = cache["self"]["k"].shape[2]
    slot = pos % n_slots
    bidx = jnp.arange(B)
    slot_positions = cache["slot_positions"].at[bidx, slot].set(pos)

    def block(x, inp):
        bp, kv, ckv = inp
        xn = apply_norm(cfg.norm_type, bp["self_norm"], x)
        q, k, v = layers.project_qkv(bp["self_attn"], xn, pos[:, None], cfg)
        nk = kv["k"].at[bidx, slot].set(k[:, 0].astype(kv["k"].dtype))
        nv = kv["v"].at[bidx, slot].set(v[:, 0].astype(kv["v"].dtype))
        a = layers.decode_attention(q, nk, nv, slot_positions, pos)
        x = x + layers.project_out(bp["self_attn"], a, cfg)
        x = _cross_attention(bp, x, ckv, cfg)
        xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
        x = x + layers.apply_mlp(bp["mlp"], xm, cfg)
        return x, {"k": nk, "v": nv}

    x, new_self = jax.lax.scan(
        block, x, (params["dec_blocks"], cache["self"], cache["cross"]))
    x = apply_norm(cfg.norm_type, params["dec_final_norm"], x)
    logits = layers.lm_logits(None, params["embed"], x, True)
    return logits, {
        "self": new_self,
        "cross": cache["cross"],
        "slot_positions": slot_positions,
        "pos": pos + 1,
    }
