"""Mixture-of-Experts layer — GShard-style capacity dispatch, pjit-friendly.

Design (see DESIGN.md §5):
  * tokens are grouped by batch row (G = B groups of S tokens); each group
    computes its own expert capacity ``C = ceil(S * k / E * capacity_factor)``
    so the dispatch/combine einsums have static shapes; the ragged serving
    step feeds the whole flat token stream as one (1, T) group, so expert
    load balances across the entire mixed prefill+decode batch rather than
    per lane;
  * everything is expressed as einsums over one-hot dispatch tensors, so
    expert parallelism falls out of pjit sharding constraints
    (experts -> "model" axis, groups -> "data" axis) and the token
    all-to-all is induced by XLA, not hand-written;
  * DeepSeek-style shared experts are a dense MLP added to every token;
  * the router computes a GShard auxiliary load-balance loss.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import common, layers
from repro.models.common import constrain, dense_init, split_keys


# ---------------------------------------------------------------------------
def moe_params(key, cfg: ArchConfig) -> Dict:
    """Parameters for one MoE layer (router + routed experts + shared)."""
    mo = cfg.moe
    assert mo is not None
    d, E, h = cfg.d_model, mo.n_experts, mo.d_expert
    kr, kg, ku, kd, ks = split_keys(key, 5)
    p = {
        "router": dense_init(kr, (d, E), scale=1.0),
        # stacked expert weights: leading axis = expert (sharded on "model")
        "experts_w_gate": dense_init(kg, (E, d, h), in_axis=1),
        "experts_w_up": dense_init(ku, (E, d, h), in_axis=1),
        "experts_w_down": dense_init(kd, (E, h, d), in_axis=1, scale=1.0),
    }
    if mo.n_shared_experts:
        shared_ff = mo.d_expert * mo.n_shared_experts
        p["shared"] = layers.mlp_params(ks, cfg, d_ff=shared_ff)
    return p


def expert_capacity(n_tokens_per_group: int, mo: MoEConfig) -> int:
    c = math.ceil(n_tokens_per_group * mo.experts_per_token
                  / mo.n_experts * mo.capacity_factor)
    return max(1, c)


# ---------------------------------------------------------------------------
def route_topk(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (..., E) -> (gates (..., k), indices (..., k)).

    Gates are softmax probabilities renormalized over the selected k.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(axis=-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(probs: jax.Array, dispatch_counts: jax.Array,
                      n_experts: int) -> jax.Array:
    """GShard aux loss: E * mean_e(frac_tokens_e * mean_prob_e)."""
    # probs: (G, S, E) softmax router probs; dispatch_counts: (G, S, E) 0/1
    me = probs.mean(axis=(0, 1))                       # (E,)
    ce = dispatch_counts.astype(jnp.float32).mean(axis=(0, 1))  # (E,)
    return n_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
def apply_moe(p: Dict, x: jax.Array, cfg: ArchConfig,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).

    B is the group axis (G = B).  All shapes static; capacity-dropped tokens
    fall back to the shared experts / residual only.  Dispatch strategy is
    ``cfg.moe.impl``: "gshard" (einsum baseline) or "gather" (§Perf-1).

    Expert parallelism needs no serving-specific code: the ragged engine
    feeds the flat token stream as one (1, T) group, the expert stacks
    ``experts_w_*`` arrive sharded over "model" on their leading E axis
    (launch/sharding.py rule table), and the dispatch/combine einsums
    partition along the contraction's E dim by GSPMD propagation — each
    shard computes its local experts' capacity slabs and the combine
    all-reduces over "model".  When an explicit mesh context is active the
    shard_map combine below replaces the einsum combine.
    """
    mo = cfg.moe
    assert mo is not None
    if mo.impl == "gather":
        return apply_moe_gather(p, x, cfg)
    B, S, d = x.shape
    E, k = mo.n_experts, mo.experts_per_token
    C = expert_capacity(S, mo)
    dt = x.dtype

    # ---- router (fp32) ----------------------------------------------------
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    logits = constrain(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)            # (G,S,E)
    gates, idx = route_topk(logits, k)                 # (G,S,k)

    # one-hot expert choice per slot: (G,S,k,E)
    choice = jax.nn.one_hot(idx, E, dtype=jnp.float32)

    # position-in-expert for capacity: cumulative count of earlier claims on
    # the same expert, ordered (token, slot).  flatten slots into the token
    # order so slot 0 of token t precedes slot 0 of token t+1.
    flat = choice.reshape(B, S * k, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat          # (G, S*k, E)
    pos_in_e = pos_in_e.reshape(B, S, k, E)
    within_cap = (pos_in_e < C)
    keep = choice * within_cap                          # (G,S,k,E) 0/1

    aux = load_balance_loss(probs, keep.sum(axis=2), E)

    # capacity-slot one-hot (G,S,k,C); dispatch/combine materialized directly
    # in compute dtype — these are the big (G,S,E,C) tensors (sharded over
    # groups -> data and experts -> model).
    slot = jax.nn.one_hot(
        jnp.sum(pos_in_e * choice, axis=-1).astype(jnp.int32), C,
        dtype=dt)                                       # (G,S,k,C)
    keep_c = keep.astype(dt)
    dispatch = jnp.einsum("gske,gskc->gsec", keep_c, slot)          # (G,S,E,C)
    combine = jnp.einsum("gske,gsk,gskc->gsec",
                         keep_c, gates.astype(dt), slot)

    # ---- dispatch -> expert compute -> combine -----------------------------
    # explicit constraints keep tokens batch-sharded and experts
    # model-sharded through the layer (propagation alone replicates here)
    dispatch = constrain(dispatch, "batch", None, "model", None)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, x)                  # (E,G,C,d)
    xe = constrain(xe, "model", "batch", None, None)
    act = "silu" if cfg.mlp_type == "swiglu" else "gelu"
    g = jnp.einsum("egcd,edh->egch", xe, p["experts_w_gate"].astype(dt))
    u = jnp.einsum("egcd,edh->egch", xe, p["experts_w_up"].astype(dt))
    if act == "silu":
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g, approximate=True) * u
    ye = jnp.einsum("egch,ehd->egcd", h, p["experts_w_down"].astype(dt))
    ye = constrain(ye, "model", "batch", None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)                  # (G,S,d)
    y = constrain(y, "batch", None, None)

    # ---- shared experts -----------------------------------------------------
    if mo.n_shared_experts:
        y = y + layers.apply_mlp(p["shared"], x, cfg)

    return y, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# §Perf-1: gather/scatter dispatch — zero-FLOP routing (beyond paper).
#
# The GShard one-hot dispatch/combine einsums cost 4*E*C*d MACs per token —
# for qwen3-moe at 32k prefill that is 84% of ALL program FLOPs (see
# EXPERIMENTS.md §Roofline).  This path builds integer routing tables and
# uses gather (dispatch) + gather-and-weight (combine) instead; autodiff
# turns the gathers into scatter-adds, still zero MACs.
# ---------------------------------------------------------------------------
def apply_moe_gather(p: Dict, x: jax.Array, cfg: ArchConfig,
                     ) -> Tuple[jax.Array, jax.Array]:
    mo = cfg.moe
    assert mo is not None
    B, S, d = x.shape
    E, k = mo.n_experts, mo.experts_per_token
    C = expert_capacity(S, mo)
    dt = x.dtype

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    logits = constrain(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = route_topk(logits, k)                  # (G,S,k)

    choice = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G,S,k,E)
    flat = choice.reshape(B, S * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, k, E)
    within_cap = pos_in_e < C
    keep = choice * within_cap                          # (G,S,k,E) 0/1
    aux = load_balance_loss(probs, keep.sum(axis=2), E)

    keep_slot = jnp.sum(keep, axis=-1)                  # (G,S,k) 0/1
    slot_c = jnp.sum(pos_in_e * choice, axis=-1).astype(jnp.int32)  # (G,S,k)

    # routing table: (G, E, C) -> source token index + validity.
    # kept batch-sharded / expert-REPLICATED: the table is tiny (int32) and
    # a data-dependent scatter across a model-sharded E would force SPMD to
    # replicate the whole router region over "data" (§Perf-1 iter 6).
    s_idx = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, k))
    g_idx = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, k))
    buf = jnp.zeros((B, E, C), jnp.int32)
    buf = constrain(buf, "batch", None, None)
    # (token+1) so 0 marks an empty capacity slot; kept (e,c) pairs are
    # unique per group, so scatter-add has no collisions
    buf = buf.at[g_idx, idx, slot_c].add(
        ((s_idx + 1) * keep_slot).astype(jnp.int32))
    buf = constrain(buf, "batch", None, None)
    valid = buf > 0                                     # (G,E,C)
    tok = jnp.maximum(buf - 1, 0)

    # dispatch: pure gather along S
    tok = constrain(tok, "batch", None, None)
    xe = jax.vmap(lambda xg, tg: xg[tg])(x, tok)         # (G,E,C,d)
    xe = xe * valid[..., None].astype(dt)
    xe = jnp.swapaxes(xe, 0, 1)                          # (E,G,C,d)
    xe = constrain(xe, "model", "batch", None, None)

    act = "silu" if cfg.mlp_type == "swiglu" else "gelu"
    g = jnp.einsum("egcd,edh->egch", xe, p["experts_w_gate"].astype(dt))
    u = jnp.einsum("egcd,edh->egch", xe, p["experts_w_up"].astype(dt))
    h = (jax.nn.silu(g) if act == "silu"
         else jax.nn.gelu(g, approximate=True)) * u
    ye = jnp.einsum("egch,ehd->egcd", h, p["experts_w_down"].astype(dt))
    ye = constrain(ye, "model", "batch", None, None)

    # combine (§Perf-1 iter 5): explicit expert-parallel combine via
    # shard_map — each model shard gathers from its LOCAL expert block and
    # psums the (G,S,d) result, so the cross-shard reduction happens at
    # 1x d (bf16), not at the (G,S,k,d) fp32 partials XLA's gather
    # partitioning produces (16x less all-reduce traffic).  Falls back to
    # the plain gather combine without a mesh (CPU tests) or when shapes
    # don't divide the mesh axes.
    w = (gates * keep_slot).astype(dt)                   # (G,S,k)
    y = _expert_parallel_combine(ye, idx, slot_c, w)
    if y is None:
        ye_g = jnp.swapaxes(ye, 0, 1)                    # (G,E,C,d)
        yk = jax.vmap(lambda yg, eg, cg: yg[eg, cg])(ye_g, idx, slot_c)
        y = jnp.einsum("gsk,gskd->gsd", w, yk)
    y = constrain(y, "batch", None, None)

    if mo.n_shared_experts:
        y = y + layers.apply_mlp(p["shared"], x, cfg)
    return y, aux.astype(jnp.float32)


def _expert_parallel_combine(ye, idx, slot_c, w):
    """shard_map combine: local expert gather + psum over "model".

    ye (E,G,C,d) sharded (model, batch); idx/slot_c/w (G,S,k) batch-sharded.
    Returns y (G,S,d) or None when the shard_map path doesn't apply.
    """
    mesh = common.abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None
    from jax.sharding import PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    E, G, C, d = ye.shape
    S, k = idx.shape[1], idx.shape[2]
    # pick the largest batch-axis suffix that divides G
    bspec = None
    for kk in range(len(batch_axes), 0, -1):
        axes = batch_axes[-kk:]
        n = 1
        for a in axes:
            n *= sizes[a]
        if G % n == 0 and G >= n:
            bspec = axes if len(axes) > 1 else axes[0]
            break
    if E % sizes["model"] != 0:
        return None

    def body(ye_blk, idx_blk, slot_blk, w_blk):
        # ye_blk (E_loc, G_loc, C, d); others (G_loc, S, k)
        m_idx = jax.lax.axis_index("model")
        e_loc = ye_blk.shape[0]
        local = idx_blk - m_idx * e_loc
        valid = (local >= 0) & (local < e_loc)
        local_c = jnp.clip(local, 0, e_loc - 1)
        wv = w_blk * valid.astype(w_blk.dtype)

        def per_g(ye_g, l_g, c_g, w_g):
            yk = ye_g[l_g, c_g]                   # (S, k, d)
            return jnp.einsum("sk,skd->sd", w_g, yk)

        ypart = jax.vmap(per_g)(jnp.swapaxes(ye_blk, 0, 1),
                                local_c, slot_blk, wv)
        # barrier keeps the psum on the wire in bf16 (XLA otherwise hoists
        # the downstream norm's f32 convert above the all-reduce: 2x bytes)
        return common.optimization_barrier(jax.lax.psum(ypart, "model"))

    gspec = P(bspec, None, None)
    in_specs = (P("model", bspec, None, None), gspec, gspec, gspec)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=gspec,
                  check_vma=False)(ye, idx, slot_c, w)
    # jax < 0.5: the experimental module spells the replication check
    # differently; without this the explicit combine path would crash the
    # moment a mesh context exists
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(body, mesh=mesh, in_specs=in_specs, out_specs=gspec,
                  check_rep=False)(ye, idx, slot_c, w)
