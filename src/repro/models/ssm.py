"""Mamba2 (SSD) block — chunked state-space dual form, TPU-friendly.

The sequence dimension is processed in chunks: a quadratic intra-chunk term
(MXU-friendly matmuls) plus a linear inter-chunk recurrence carried by
``lax.scan`` over chunk index.  This mirrors the Pallas kernel in
``kernels/ssm_scan.py`` (same schedule; the kernel fuses the intra-chunk math
into VMEM tiles).

Shapes follow the Mamba2 paper: heads H = d_inner / head_dim (P = head_dim),
state size N = d_state, B/C shared across heads in ``n_groups`` groups.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.common import dense_init, split_keys


def _cfg(cfg: ArchConfig) -> SSMConfig:
    assert cfg.ssm is not None
    return cfg.ssm


def dims(cfg: ArchConfig) -> Dict[str, int]:
    s = _cfg(cfg)
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return dict(d_inner=d_inner, H=H, P=s.head_dim, N=s.d_state,
                G=s.n_groups, K=s.d_conv)


# ---------------------------------------------------------------------------
def mamba2_params(key, cfg: ArchConfig) -> Dict:
    dm = dims(cfg)
    d, d_in, H, N, G, K = (cfg.d_model, dm["d_inner"], dm["H"], dm["N"],
                           dm["G"], dm["K"])
    conv_dim = d_in + 2 * G * N
    k1, k2, k3, k4 = split_keys(key, 4)
    # dt bias: inverse softplus of dt ~ U[1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(k3, (H,), jnp.float32)
                 * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a = jax.random.uniform(k4, (H,), jnp.float32, 1.0, 16.0)
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": dense_init(k1, (d, 2 * d_in + 2 * G * N + H)),
        "conv_w": (jax.random.normal(k2, (K, conv_dim), jnp.float32)
                   * (1.0 / (K * conv_dim) ** 0.5)),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(split_keys(key, 5)[4], (d_in, d), scale=1.0),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ArchConfig):
    dm = dims(cfg)
    d_in, G, N, H = dm["d_inner"], dm["G"], dm["N"], dm["H"]
    z, x, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N],
        axis=-1)
    return z, x, Bc, Cc, dt


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B,S,Cd); w: (K,Cd). state: (B,K-1,Cd)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    return out + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD scan (pure jnp; oracle for kernels/ssm_scan.py)
# ---------------------------------------------------------------------------
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """State-space dual chunked scan.

    x:  (B, L, H, P)   inputs per head
    dt: (B, L, H)      positive step sizes (already softplus'd)
    A:  (H,)           negative decay rates
    Bm: (B, L, G, N)   input maps; Cm: (B, L, G, N) output maps
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    rep = H // G

    # broadcast groups to heads
    Bh = jnp.repeat(Bm, rep, axis=2)      # (B,L,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xr = x.reshape(B, nc, Q, H, P)
    dtr = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    Br = Bh.reshape(B, nc, Q, H, N)
    Cr = Ch.reshape(B, nc, Q, H, N)

    dA = dtr * A[None, None, None, :]               # (B,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                    # inclusive cumsum in chunk

    # intra-chunk decay matrix: decay[i,j] = exp(cum_i - cum_j) for j<=i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)

    xdt = xr * dtr[..., None].astype(x.dtype)       # (B,nc,Q,H,P)

    # intra-chunk (diagonal block) output
    CB = jnp.einsum("bcqhn,bckhn->bcqkh", Cr, Br).astype(jnp.float32)
    W = CB * Lmat                                   # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", W.astype(x.dtype), xdt)

    # per-chunk input to the recurrent state
    decay_last = jnp.exp(cum[:, :, -1:, :] - cum)   # (B,nc,Q,H)
    states_in = jnp.einsum("bckhn,bckh,bckhp->bchpn",
                           Br, decay_last.astype(x.dtype), xdt)  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # (B,nc,H) total decay

    def chunk_step(state, inp):
        s_in, cdecay = inp                          # (B,H,P,N), (B,H)
        out_state = state                           # state BEFORE this chunk
        new_state = state * cdecay[..., None, None].astype(state.dtype) + s_in
        return new_state, out_state

    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    # scan over chunk axis
    s_in_seq = jnp.moveaxis(states_in.astype(jnp.float32), 1, 0)
    cdecay_seq = jnp.moveaxis(chunk_decay, 1, 0)
    final_state, prev_states = jax.lax.scan(
        chunk_step, s0, (s_in_seq, cdecay_seq))
    prev_states = jnp.moveaxis(prev_states, 0, 1)   # (B,nc,H,P,N)

    # inter-chunk (off-diagonal) output: contribution of carried state
    in_decay = jnp.exp(cum)                         # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Cr, prev_states.astype(x.dtype),
                       in_decay.astype(x.dtype))

    y = (y_diag + y_off).reshape(B, L, H, P)
    return y, final_state


def ssd_decode_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A: jax.Array, B_t: jax.Array, C_t: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step.  state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    B_t/C_t (B,G,N) -> broadcast to heads."""
    B, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)               # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A)      # (B,H)
    upd = jnp.einsum("bhp,bhn->bhpn", (x_t * dt_t[..., None]).astype(jnp.float32),
                     Bh.astype(jnp.float32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    return new_state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------
def _gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    xf = (x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def apply_mamba2(p: Dict, x: jax.Array, cfg: ArchConfig,
                 state: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,S,d).  state (decode): {"ssm": (B,H,P,N), "conv": (B,K-1,Cd)}.

    Training/prefill: state=None, chunked scan, returns (y, None).
    Decode: S==1, returns (y, new_state).
    """
    dm = dims(cfg)
    H, P, N, G, K = dm["H"], dm["P"], dm["N"], dm["G"], dm["K"]
    Bsz, S, _ = x.shape
    dt_ = x.dtype

    zxbcdt = x @ p["w_in"].astype(dt_)
    z, xin, Bc, Cc, dt_raw = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)

    if state is None:
        conv_out = causal_conv1d(conv_in, p["conv_w"], p["conv_b"])
        conv_out = jax.nn.silu(conv_out)
        xin, Bc, Cc = jnp.split(conv_out, [dm["d_inner"],
                                           dm["d_inner"] + G * N], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        xh = xin.reshape(Bsz, S, H, P)
        y, _ = ssd_chunked(xh, dt, -jnp.exp(p["A_log"]),
                           Bc.reshape(Bsz, S, G, N),
                           Cc.reshape(Bsz, S, G, N),
                           chunk=_cfg(cfg).chunk_size)
        y = y + xh * p["D"].astype(dt_)[None, None, :, None]
        y = y.reshape(Bsz, S, dm["d_inner"])
        y = _gated_rmsnorm(y, z, p["norm_scale"])
        return y @ p["w_out"].astype(dt_), None

    # ---- decode: one token ------------------------------------------------
    assert S == 1
    conv_state = state["conv"]                      # (B, K-1, Cd)
    conv_out = causal_conv1d(conv_in, p["conv_w"], p["conv_b"],
                             state=conv_state)
    new_conv = jnp.concatenate([conv_state[:, 1:], conv_in], axis=1)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [dm["d_inner"],
                                       dm["d_inner"] + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    new_ssm, y = ssd_decode_step(
        state["ssm"], xin.reshape(Bsz, H, P), dt.reshape(Bsz, H),
        -jnp.exp(p["A_log"]),
        Bc.reshape(Bsz, G, N), Cc.reshape(Bsz, G, N))
    y = y + xin.reshape(Bsz, H, P) * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(Bsz, 1, dm["d_inner"])
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["w_out"].astype(dt_), {"ssm": new_ssm, "conv": new_conv}


def init_mamba2_state(cfg: ArchConfig, batch: int) -> Dict:
    dm = dims(cfg)
    conv_dim = dm["d_inner"] + 2 * dm["G"] * dm["N"]
    return {
        "ssm": jnp.zeros((batch, dm["H"], dm["P"], dm["N"]), jnp.float32),
        "conv": jnp.zeros((batch, dm["K"] - 1, conv_dim), jnp.bfloat16),
    }
