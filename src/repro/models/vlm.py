"""LLaVA-NeXT-style VLM: stubbed vision tower + real projector + LM backbone.

The CLIP ViT tower is a STUB (assignment carve-out): the model consumes
precomputed patch embeddings ``patches (B, n_tokens, d_embed)`` shaped as the
anyres tiling grid would emit (base image + tiles, 576 patches each).  The
2-layer MLP projector and the Mistral-backbone language model are real.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.common import dense_init, split_keys


def init_params(key, cfg: ArchConfig) -> Dict:
    assert cfg.frontend is not None and cfg.frontend.kind == "image_patches"
    k1, k2, k3 = split_keys(key, 3)
    return {
        "projector": {
            "w1": dense_init(k1, (cfg.frontend.d_embed, cfg.d_model)),
            "b1": jnp.zeros((cfg.d_model,), jnp.float32),
            "w2": dense_init(k2, (cfg.d_model, cfg.d_model)),
            "b2": jnp.zeros((cfg.d_model,), jnp.float32),
        },
        "lm": transformer.init_params(k3, cfg),
    }


def project_patches(params: Dict, patches: jax.Array,
                    compute_dtype=jnp.bfloat16) -> jax.Array:
    p = params["projector"]
    x = patches.astype(compute_dtype)
    x = jax.nn.gelu(x @ p["w1"].astype(compute_dtype)
                    + p["b1"].astype(compute_dtype), approximate=True)
    return x @ p["w2"].astype(compute_dtype) + p["b2"].astype(compute_dtype)


def forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
            patches: Optional[jax.Array] = None, window: int = 0,
            compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    extra = (project_patches(params, patches, compute_dtype)
             if patches is not None else None)
    return transformer.forward(params["lm"], tokens, cfg, window=window,
                               extra_embeds=extra,
                               compute_dtype=compute_dtype,
                               attn_chunk=attn_chunk, remat=remat)


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *,
            window: int = 0, attn_chunk: int = 512,
            remat: bool = True) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg,
                          patches=batch.get("patches"), window=window,
                          attn_chunk=attn_chunk, remat=remat)
    labels = batch["labels"]
    if batch.get("patches") is not None:
        pad = -jnp.ones(batch["patches"].shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    aw = cfg.moe.router_aux_weight if cfg.moe else 0.0
    return transformer.lm_loss(logits, labels, aux, aw)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Dict:
    return transformer.init_cache(cfg, batch, cache_len, window=window,
                                  dtype=dtype)


def decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ArchConfig, *, window: int = 0,
                compute_dtype=jnp.bfloat16):
    # image patches enter during prefill; token-by-token decode is text-only
    return transformer.decode_step(params["lm"], cache, tokens, cfg,
                                   window=window,
                                   compute_dtype=compute_dtype)


def init_paged_cache(cfg: ArchConfig, n_lanes: int, **kw) -> Dict:
    return transformer.init_paged_cache(cfg, n_lanes, **kw)


def paged_step(params: Dict, cache: Dict, tokens: jax.Array,
               cfg: ArchConfig, *, window: int = 0,
               compute_dtype=jnp.bfloat16, use_kernel=None):
    # image patches enter during prefill; the unified chunked step serves
    # the text backbone (prefill chunks and decode share one compiled path)
    return transformer.paged_step(params["lm"], cache, tokens, cfg,
                                  window=window,
                                  compute_dtype=compute_dtype,
                                  use_kernel=use_kernel)


def ragged_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ArchConfig, *, window: int = 0, tile: int = 16,
                compute_dtype=jnp.bfloat16, use_kernel=None):
    # the flat-token serving step sees text tokens only (patches entered
    # during prefill); the LM backbone consumes the ragged stream directly,
    # segment-tiled whenever the engine ships tile_meta/row_tile in the
    # cache (``tile`` = static q-window rows of that TileMap).  Like the
    # text backbone it returns logits for every stream row — the
    # speculative-decode verification contract — so draft segments verify
    # through the VLM path unchanged.
    return transformer.ragged_step(params["lm"], cache, tokens, cfg,
                                   window=window, tile=tile,
                                   compute_dtype=compute_dtype,
                                   use_kernel=use_kernel)


def paged_decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                      cfg: ArchConfig, *, window: int = 0,
                      compute_dtype=jnp.bfloat16):
    return transformer.paged_decode_step(params["lm"], cache, tokens, cfg,
                                         window=window,
                                         compute_dtype=compute_dtype)
