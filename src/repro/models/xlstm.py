"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory with recurrent gate connections, sequential scan).

mLSTM uses the stabilized chunkwise-parallel formulation (intra-chunk
quadratic + inter-chunk (C, n, m) recurrence) — the TPU-friendly form; the
recurrent step form is used for decode.  sLSTM has true recurrent weight
connections (R acts on h_{t-1}) so it is inherently sequential; we scan over
time, which is also what the reference CUDA kernel does.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, XLSTMConfig
from repro.models.common import apply_norm, dense_init, norm_params, split_keys
from repro.models.ssm import causal_conv1d


def _x(cfg: ArchConfig) -> XLSTMConfig:
    assert cfg.xlstm is not None
    return cfg.xlstm


def mlstm_dims(cfg: ArchConfig) -> Dict[str, int]:
    d_in = int(cfg.d_model * _x(cfg).mlstm_proj_factor)
    H = cfg.n_heads
    return dict(d_in=d_in, H=H, hd=d_in // H)


# ---------------------------------------------------------------------------
# mLSTM block (pre-up-projection)
# ---------------------------------------------------------------------------
def mlstm_params(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    dm = mlstm_dims(cfg)
    d_in, H, hd = dm["d_in"], dm["H"], dm["hd"]
    K = _x(cfg).conv1d_kernel
    ks = split_keys(key, 8)
    return {
        "norm": norm_params(cfg.norm_type, d),
        "w_up": dense_init(ks[0], (d, d_in)),
        "w_z": dense_init(ks[1], (d, d_in)),
        "conv_w": (jax.random.normal(ks[2], (K, d_in), jnp.float32)
                   * (1.0 / (K * d_in) ** 0.5)),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "wq": dense_init(ks[3], (d_in, d_in)),
        "wk": dense_init(ks[4], (d_in, d_in)),
        "wv": dense_init(ks[5], (d_in, d_in)),
        "w_if": dense_init(ks[6], (d_in, 2 * H), scale=0.1),
        "b_i": jnp.zeros((H,), jnp.float32),
        # forget-gate bias init positive => long memory at init
        "b_f": jnp.linspace(3.0, 6.0, H),
        "out_norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_down": dense_init(ks[7], (d_in, d), scale=1.0),
    }


def mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int,
                    state: Optional[Tuple] = None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, L, H, hd); log_i/log_f: (B, L, H) fp32.
    Returns (h (B,L,H,hd), (C (B,H,hd,hd), n (B,H,hd), m (B,H))).
    """
    B, L, H, hd = q.shape
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    scale = 1.0 / (hd ** 0.5)

    qr = (q * scale).reshape(B, nc, Q, H, hd)
    kr = k.reshape(B, nc, Q, H, hd)
    vr = v.reshape(B, nc, Q, H, hd)
    lir = log_i.reshape(B, nc, Q, H)
    lfr = log_f.reshape(B, nc, Q, H)
    b = jnp.cumsum(lfr, axis=2)                     # inclusive cumsum of log f
    bQ = b[:, :, -1, :]                             # (B,nc,H) chunk total

    # intra-chunk log weights: w[t,j] = b_t - b_j + li_j  (j <= t)
    wmat = (b[:, :, :, None, :] - b[:, :, None, :, :]
            + lir[:, :, None, :, :])                # (B,nc,Qt,Qj,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    wmat = jnp.where(tri[None, None, :, :, None], wmat, -jnp.inf)
    w_max = wmat.max(axis=3)                        # (B,nc,Qt,H) local max

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C_prev, n_prev, m_prev = carry
        q_c, k_c, v_c, li_c, b_c, bQ_c, w_c, wmax_c = inp
        # q_c (B,Q,H,hd) ... w_c (B,Qt,Qj,H), wmax_c (B,Qt,H)

        # per-position stabilizer
        m_pos = jnp.maximum(wmax_c, b_c + m_prev[:, None, :])   # (B,Q,H)

        # intra-chunk
        s = jnp.einsum("bqhd,bjhd->bqjh", q_c, k_c).astype(jnp.float32)
        D = jnp.exp(w_c - m_pos[:, :, None, :])
        S = s * D
        num_intra = jnp.einsum("bqjh,bjhd->bqhd", S.astype(q.dtype), v_c)
        den_intra = S.sum(axis=2)                                # (B,Q,H)

        # inter-chunk (carried state)
        inter_w = jnp.exp(b_c + m_prev[:, None, :] - m_pos)     # (B,Q,H)
        num_inter = jnp.einsum("bqhd,bhde->bqhe", q_c,
                               C_prev.astype(q.dtype))
        num_inter = num_inter * inter_w[..., None].astype(q.dtype)
        den_inter = jnp.einsum("bqhd,bhd->bqh", q_c.astype(jnp.float32),
                               n_prev) * inter_w

        num = num_intra.astype(jnp.float32) + num_inter.astype(jnp.float32)
        den = den_intra + den_inter
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_pos))
        h_c = (num / denom[..., None]).astype(q.dtype)

        # state update
        upd_w = bQ_c[:, None, :] - b_c + li_c                    # (B,Q,H)
        m_new = jnp.maximum(bQ_c + m_prev, upd_w.max(axis=1))    # (B,H)
        k_scaled = k_c.astype(jnp.float32) * jnp.exp(
            upd_w - m_new[:, None, :])[..., None]
        C_new = (C_prev * jnp.exp(bQ_c + m_prev - m_new)[..., None, None]
                 + jnp.einsum("bqhd,bqhe->bhde", k_scaled,
                              v_c.astype(jnp.float32)))
        n_new = (n_prev * jnp.exp(bQ_c + m_prev - m_new)[..., None]
                 + k_scaled.sum(axis=1))
        return (C_new, n_new, m_new), h_c

    xs = (jnp.moveaxis(qr, 1, 0), jnp.moveaxis(kr, 1, 0),
          jnp.moveaxis(vr, 1, 0), jnp.moveaxis(lir, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(bQ, 1, 0),
          jnp.moveaxis(wmat, 1, 0), jnp.moveaxis(w_max, 1, 0))
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L, H, hd)
    return h, (Cf, nf, mf)


def mlstm_decode_step(state, q, k, v, log_i, log_f):
    """One step.  state (C,n,m); q/k/v (B,H,hd); gates (B,H) fp32."""
    C_prev, n_prev, m_prev = state
    hd = q.shape[-1]
    q = q * (1.0 / hd ** 0.5)
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_eff = jnp.exp(log_f + m_prev - m_new)
    i_eff = jnp.exp(log_i - m_new)
    kf = k.astype(jnp.float32) * i_eff[..., None]
    C_new = C_prev * f_eff[..., None, None] + jnp.einsum(
        "bhd,bhe->bhde", kf, v.astype(jnp.float32))
    n_new = n_prev * f_eff[..., None] + kf
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C_new)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_new)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = (num / denom[..., None]).astype(q.dtype)
    return (C_new, n_new, m_new), h


def _multihead_rmsnorm(x: jax.Array, scale: jax.Array, H: int,
                       eps: float = 1e-6) -> jax.Array:
    """Head-wise RMSNorm over (B,S,H,hd) flattened scale (d_in,)."""
    B, S, d_in = x.shape
    hd = d_in // H
    xf = x.astype(jnp.float32).reshape(B, S, H, hd)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = (xf * jax.lax.rsqrt(var + eps)).reshape(B, S, d_in) * scale
    return out.astype(x.dtype)


def apply_mlstm_block(p: Dict, x: jax.Array, cfg: ArchConfig,
                      state: Optional[Dict] = None,
                      chunk: int = 128) -> Tuple[jax.Array, Optional[Dict]]:
    """Residual mLSTM block.  x (B,S,d)."""
    dm = mlstm_dims(cfg)
    d_in, H, hd = dm["d_in"], dm["H"], dm["hd"]
    B, S, _ = x.shape
    dt = x.dtype

    xn = apply_norm(cfg.norm_type, p["norm"], x)
    x_up = xn @ p["w_up"].astype(dt)
    z_up = xn @ p["w_z"].astype(dt)

    if state is None:
        conv = jax.nn.silu(causal_conv1d(x_up, p["conv_w"], p["conv_b"]))
        new_conv = None
    else:
        conv = jax.nn.silu(causal_conv1d(x_up, p["conv_w"], p["conv_b"],
                                         state=state["conv"]))
        new_conv = jnp.concatenate([state["conv"][:, 1:], x_up], axis=1)

    q = (conv @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (conv @ p["wk"].astype(dt)).reshape(B, S, H, hd)
    v = (x_up @ p["wv"].astype(dt)).reshape(B, S, H, hd)
    gates = (x_up.astype(jnp.float32) @ p["w_if"].astype(jnp.float32))
    log_i = gates[..., :H] + p["b_i"]
    log_f = jax.nn.log_sigmoid(gates[..., H:] + p["b_f"])

    if state is None:
        h, _ = mlstm_chunkwise(q, k, v, log_i, log_f, chunk)
        new_state = None
    else:
        assert S == 1
        (C, n, m), h = mlstm_decode_step(
            (state["C"], state["n"], state["m"]),
            q[:, 0], k[:, 0], v[:, 0], log_i[:, 0], log_f[:, 0])
        h = h[:, None]
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}

    h = h.reshape(B, S, d_in)
    h = _multihead_rmsnorm(h, p["out_norm_scale"], H)
    h = h * jax.nn.silu(z_up)
    return x + h @ p["w_down"].astype(dt), new_state


def init_mlstm_state(cfg: ArchConfig, batch: int) -> Dict:
    dm = mlstm_dims(cfg)
    K = _x(cfg).conv1d_kernel
    return {
        "C": jnp.zeros((batch, dm["H"], dm["hd"], dm["hd"]), jnp.float32),
        "n": jnp.zeros((batch, dm["H"], dm["hd"]), jnp.float32),
        "m": jnp.full((batch, dm["H"]), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, K - 1, dm["d_in"]), jnp.bfloat16),
    }


# ---------------------------------------------------------------------------
# sLSTM block (post-up-projection) — sequential scan
# ---------------------------------------------------------------------------
def slstm_params(key, cfg: ArchConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = split_keys(key, 7)
    pf = _x(cfg).slstm_proj_factor
    ff = int(d * pf)
    return {
        "norm": norm_params(cfg.norm_type, d),
        # input weights for 4 gates (i, f, z, o)
        "w_gates": dense_init(ks[0], (d, 4 * d)),
        # block-diagonal recurrent weights per head, per gate
        "r_gates": dense_init(ks[1], (4, H, hd, hd), in_axis=2, scale=0.5),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "out_norm": norm_params(cfg.norm_type, d),
        # gated FFN (proj factor ~4/3)
        "ffn_norm": norm_params(cfg.norm_type, d),
        "ffn_gate": dense_init(ks[2], (d, ff)),
        "ffn_up": dense_init(ks[3], (d, ff)),
        "ffn_down": dense_init(ks[4], (ff, d), scale=1.0),
    }


def slstm_scan(p: Dict, xn: jax.Array, H: int,
               state: Optional[Tuple] = None):
    """xn: (B,S,d) pre-normed input.  Sequential over S.

    Returns (h (B,S,d), final_state (c, n, m, h_prev) each (B,d) fp32)."""
    B, S, d = xn.shape
    hd = d // H
    gates_in = (xn.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)
                + p["b_gates"])                      # (B,S,4d)

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, m0, h0 = state

    r = p["r_gates"].astype(jnp.float32)             # (4,H,hd,hd)

    def step(carry, g_t):
        c, n, m, h_prev = carry
        hp = h_prev.reshape(B, H, hd)
        rec = jnp.einsum("bhd,ghde->gbhe", hp, r).reshape(4, B, d)
        gi, gf, gz, go = (g_t[..., :d] + rec[0],
                          g_t[..., d:2 * d] + rec[1],
                          g_t[..., 2 * d:3 * d] + rec[2],
                          g_t[..., 3 * d:] + rec[3])
        log_f = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(log_f + m, gi)
        i_eff = jnp.exp(gi - m_new)
        f_eff = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c_new = f_eff * c + i_eff * z
        n_new = f_eff * n + i_eff
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (cf, nf, mf, hf), hs = jax.lax.scan(
        step, (c0, n0, m0, h0), jnp.moveaxis(gates_in, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (cf, nf, mf, hf)


def apply_slstm_block(p: Dict, x: jax.Array, cfg: ArchConfig,
                      state: Optional[Dict] = None
                      ) -> Tuple[jax.Array, Optional[Dict]]:
    H = cfg.n_heads
    dt = x.dtype
    xn = apply_norm(cfg.norm_type, p["norm"], x)
    if state is None:
        h, _ = slstm_scan(p, xn, H)
        new_state = None
    else:
        h, (c, n, m, hf) = slstm_scan(
            p, xn, H, state=(state["c"], state["n"], state["m"], state["h"]))
        new_state = {"c": c, "n": n, "m": m, "h": hf}
    h = apply_norm(cfg.norm_type, p["out_norm"], h.astype(dt))
    x = x + h
    # gated FFN
    xf = apply_norm(cfg.norm_type, p["ffn_norm"], x)
    g = jax.nn.gelu(xf @ p["ffn_gate"].astype(dt), approximate=True)
    u = xf @ p["ffn_up"].astype(dt)
    x = x + (g * u) @ p["ffn_down"].astype(dt)
    return x, new_state


def init_slstm_state(cfg: ArchConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }
