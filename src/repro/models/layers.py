"""Core transformer layers: GQA attention (chunked flash-style), MLPs, embed.

Attention has three execution paths:
  * ``full``   — plain masked einsum softmax; used for small sequences.
  * ``chunked``— double-loop online-softmax (flash-style) in pure jnp; the
                 XLA path for long sequences; the inner loop over KV chunks
                 has a *dynamic* trip count so causal/windowed bands do no
                 wasted work.  This mirrors the Pallas kernel's schedule
                 (kernels/flash_attention.py) and is its oracle cousin.
  * ``decode`` — one query token against a (possibly rolling) KV cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import apply_norm, apply_rope, dense_init, split_keys

NEG_INF = -1e30


def _model_axis_size() -> int:
    mesh = common.abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]


def maybe_expand_kv(q: jax.Array, k: jax.Array, v: jax.Array):
    """§Perf-4: when Q-heads divide the model axis but KV-heads don't, the
    (Hkv, G) GQA grouping forces XLA to reshard scores per chunk (observed
    as 10.9 GB all-reduces per q-chunk on starcoder2: 36H/4kv on a 16-way
    axis).  Repeating KV to H heads keeps every attention einsum local —
    a memory-for-collectives trade that wins by orders of magnitude."""
    H, Hkv = q.shape[2], k.shape[2]
    m = _model_axis_size()
    if m > 1 and H % m == 0 and Hkv % m != 0 and H != Hkv:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


# ---------------------------------------------------------------------------
# Attention parameters
# ---------------------------------------------------------------------------
def attention_params(key, cfg: ArchConfig) -> Dict:
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = split_keys(key, 4)
    p = {
        "wq": dense_init(kq, (d, H, D)),
        "wk": dense_init(kk, (d, Hkv, D)),
        "wv": dense_init(kv, (d, Hkv, D)),
        "wo": dense_init(ko, (H, D, d), in_axis=0, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    # scale wo fan-in correctly: treat (H*D) as fan-in
    p["wo"] = p["wo"] * (D ** 0.5) / ((H * D) ** 0.5)
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, D), jnp.float32)
        p["bk"] = jnp.zeros((Hkv, D), jnp.float32)
        p["bv"] = jnp.zeros((Hkv, D), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((D,), jnp.float32)
        p["k_norm_scale"] = jnp.ones((D,), jnp.float32)
    return p


def _headwise_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def project_qkv(p: Dict, x: jax.Array, positions: jax.Array, cfg: ArchConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,Hkv,D), RoPE'd."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _headwise_rmsnorm(q, p["q_norm_scale"])
        k = _headwise_rmsnorm(k, p["k_norm_scale"])
    if cfg.max_decoder_positions:      # learned positions handled elsewhere
        return q, k, v
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_out(p: Dict, attn_out: jax.Array, cfg: ArchConfig) -> jax.Array:
    """attn_out: (B, S, H, D) -> (B, S, d_model)."""
    y = jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(attn_out.dtype))
    # barrier keeps the row-parallel psum this contraction induces in bf16
    # (XLA otherwise hoists the next norm's f32 convert above it: 2x bytes)
    y = common.optimization_barrier(y)
    if cfg.use_bias:
        y = y + p["bo"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Full (small-seq) attention
# ---------------------------------------------------------------------------
def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D).  Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, k).astype(jnp.float32)
    scores = scores / (D ** 0.5)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bqkgs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# Chunked flash-style attention (XLA path for long sequences)
# ---------------------------------------------------------------------------
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int = 0, chunk: int = 512) -> jax.Array:
    """Causal (optionally sliding-window) chunked attention, differentiable.

    Two schedules (both reverse-mode differentiable, both rematerialized per
    chunk so backward memory stays O(S*chunk) instead of O(S^2)):
      * sliding window — outer scan over q chunks; each chunk attends to a
        statically-sized band of keys fetched with ``dynamic_slice``
        (work is O(S * window), the band, not the full quadratic);
      * causal full — outer scan over q chunks, inner scan over kv chunks
        with ``lax.cond`` skipping chunks above the diagonal.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    nq = S // C
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, nq, C, Hkv, G, D)

    if window and window + C < S:
        Lb = window + C                      # static band length

        def band_attn(q_i, k_band, v_band, qpos, kpos):
            s = jnp.einsum("bqkgd,bskd->bqkgs", q_i * scale,
                           k_band).astype(jnp.float32)
            mask = (kpos[None, :] <= qpos[:, None]) & \
                   (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            w = jax.nn.softmax(s, axis=-1).astype(q_i.dtype)
            return jnp.einsum("bqkgs,bskd->bqkgd", w, v_band)

        band_attn = jax.checkpoint(band_attn)

        def q_chunk_step(_, i):
            q_i = qg[:, i]
            qpos = i * C + jnp.arange(C)
            start = jnp.clip(i * C + C - Lb, 0, S - Lb)
            k_band = jax.lax.dynamic_slice_in_dim(k, start, Lb, axis=1)
            v_band = jax.lax.dynamic_slice_in_dim(v, start, Lb, axis=1)
            kpos = start + jnp.arange(Lb)
            return None, band_attn(q_i, k_band, v_band, qpos, kpos)

        _, chunks = jax.lax.scan(q_chunk_step, None, jnp.arange(nq))
        out = jnp.moveaxis(chunks, 0, 1).reshape(B, S, Hkv, G, D)
        return out.reshape(B, S, H, D)

    # ---- causal (or window wider than seq) online-softmax schedule -------
    def kv_compute(carry, q_i, qpos, j):
        m, l, acc = carry
        k_j = jax.lax.dynamic_slice_in_dim(k, j * C, C, axis=1)
        v_j = jax.lax.dynamic_slice_in_dim(v, j * C, C, axis=1)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q_i * scale,
                       k_j).astype(jnp.float32)
        kpos = j * C + jnp.arange(C)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p_ij = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p_ij.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p_ij.astype(q.dtype), v_j
        ).astype(jnp.float32)
        return m_new, l_new, acc_new

    kv_compute = jax.checkpoint(kv_compute, static_argnums=())

    def q_chunk_step(_, i):
        q_i = qg[:, i]
        qpos = i * C + jnp.arange(C)
        m0 = jnp.full((B, C, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, C, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, C, Hkv, G, D), jnp.float32)

        def kv_step(carry, j):
            new = jax.lax.cond(
                j <= i,
                lambda c: kv_compute(c, q_i, qpos, j),
                lambda c: c,
                carry)
            return new, None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nq))
        out_i = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out_i.astype(q.dtype)

    _, chunks = jax.lax.scan(q_chunk_step, None, jnp.arange(nq))
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, S, Hkv, G, D)
    return out.reshape(B, S, H, D)


def causal_attention(q, k, v, *, window: int = 0,
                     chunk_threshold: int = 2048, chunk: int = 512):
    """Dispatch between full and chunked paths on sequence length."""
    k, v = maybe_expand_kv(q, k, v)
    if q.shape[1] <= chunk_threshold:
        return full_attention(q, k, v, causal=True, window=window)
    return chunked_attention(q, k, v, window=window, chunk=chunk)


# ---------------------------------------------------------------------------
# Decode attention: one new token vs a (rolling) KV cache
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_positions: jax.Array, pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """q: (B,1,H,D); caches: (B,S_slots,Hkv,D); slot_positions: (B,S_slots)
    giving the absolute token position held in each slot (-1 = empty);
    pos: (B,) current decode position."""
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s / (D ** 0.5)
    valid = (slot_positions >= 0) & (slot_positions <= pos[:, None])
    if window:
        valid &= (pos[:, None] - slot_positions) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
    return out.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_params(key, cfg: ArchConfig, d_ff: Optional[int] = None,
               d_in: Optional[int] = None) -> Dict:
    d = d_in or cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = split_keys(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        p = {
            "w_gate": dense_init(k1, (d, ff)),
            "w_up": dense_init(k2, (d, ff)),
            "w_down": dense_init(k3, (ff, d), scale=1.0),
        }
    else:  # non-gated gelu
        p = {
            "w_up": dense_init(k1, (d, ff)),
            "w_down": dense_init(k2, (ff, d), scale=1.0),
        }
    if cfg.use_bias:
        p["b_up"] = jnp.zeros((ff,), jnp.float32)
        p["b_down"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_mlp(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = "silu" if cfg.mlp_type == "swiglu" else "gelu"
        g = common.activation(act, x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        if cfg.use_bias:
            u = u + p["b_up"].astype(dt)
        h = g * u
    else:
        h = x @ p["w_up"].astype(dt)
        if cfg.use_bias:
            h = h + p["b_up"].astype(dt)
        h = common.activation("gelu", h)
    y = h @ p["w_down"].astype(dt)
    if cfg.use_bias:
        y = y + p["b_down"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embedding_params(key, vocab: int, d: int) -> Dict:
    return {"embedding": common.embed_init(key, (vocab, d))}


def embed_tokens(p: Dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    x = p["embedding"].astype(dtype)[tokens]
    # §Perf-4: the gather from the (vocab-model, d-data)-sharded table
    # otherwise REPLICATES its output over the data axis, and the whole
    # residual stream downstream inherits full-batch replication
    return common.constrain(x, "batch", None, None)


def lm_head_params(key, d: int, vocab: int) -> Dict:
    return {"w": dense_init(key, (d, vocab))}


def lm_logits(head_p: Optional[Dict], embed_p: Dict, x: jax.Array,
              tie: bool) -> jax.Array:
    if tie:
        w = embed_p["embedding"].astype(x.dtype).T
    else:
        w = head_p["w"].astype(x.dtype)
    return (x @ w).astype(jnp.float32)
