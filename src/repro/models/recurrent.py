"""Recurrent-family LMs: zamba2 (Mamba2 + shared attention) and xLSTM.

Both are assembled as *segment scans*:
  * zamba2: 9 segments x (6 mamba2 layers + one SHARED-weight attention+MLP
    block applied at segment end).  The shared block's weights are tied across
    all applications (zamba2's signature trick) — they live outside the scan.
  * xlstm:  6 segments x (7 mLSTM blocks + 1 sLSTM block)  (xLSTM[7:1]).

Decode carries per-layer recurrent state (SSM state / mLSTM matrix memory /
sLSTM scalar state) plus one KV cache per shared-attention application.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, ssm as ssm_lib, xlstm as xlstm_lib
from repro.models.common import apply_norm, norm_params, split_keys

PyTree = Any


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ===========================================================================
# zamba2-style hybrid
# ===========================================================================
def zamba_segments(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.hybrid_attn_every
    assert per and cfg.n_layers % per == 0
    return cfg.n_layers // per, per


def init_zamba_params(key, cfg: ArchConfig) -> Dict:
    n_seg, per = zamba_segments(cfg)
    keys = split_keys(key, 4 + n_seg * per)
    p = {
        "embed": layers.embedding_params(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": norm_params(cfg.norm_type, cfg.d_model),
        "head": layers.lm_head_params(keys[1], cfg.d_model, cfg.vocab_size),
        # ONE shared attention+MLP block (weights tied across applications)
        "shared": {
            "attn_norm": norm_params(cfg.norm_type, cfg.d_model),
            "attn": layers.attention_params(keys[2], cfg),
            "mlp_norm": norm_params(cfg.norm_type, cfg.d_model),
            "mlp": layers.mlp_params(keys[3], cfg),
        },
    }
    seg_params = []
    ki = 4
    for _s in range(n_seg):
        lp = []
        for _l in range(per):
            lp.append({
                "norm": norm_params(cfg.norm_type, cfg.d_model),
                "mamba": ssm_lib.mamba2_params(keys[ki], cfg),
            })
            ki += 1
        seg_params.append(_stack(lp))
    p["segments"] = _stack(seg_params)     # leaves: (n_seg, per, ...)
    return p


def _shared_attn_apply(sp: Dict, x, positions, cfg, *, window, attn_chunk=512):
    xn = apply_norm(cfg.norm_type, sp["attn_norm"], x)
    q, k, v = layers.project_qkv(sp["attn"], xn, positions, cfg)
    a = layers.causal_attention(q, k, v, window=window, chunk=attn_chunk)
    x = x + layers.project_out(sp["attn"], a, cfg)
    xm = apply_norm(cfg.norm_type, sp["mlp_norm"], x)
    return x + layers.apply_mlp(sp["mlp"], xm, cfg)


def zamba_forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
                  window: int = 0, compute_dtype=jnp.bfloat16,
                  attn_chunk: int = 512, remat: bool = True,
                  extra_embeds=None) -> Tuple[jax.Array, jax.Array]:
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)

    def mamba_step(x, lp):
        xn = apply_norm(cfg.norm_type, lp["norm"], x)
        y, _ = ssm_lib.apply_mamba2(lp["mamba"], xn, cfg)
        return x + y, None

    def seg_step(x, seg):
        def inner(x_):
            h, _ = jax.lax.scan(mamba_step, x_, seg)
            return _shared_attn_apply(params["shared"], h, positions, cfg,
                                      window=window, attn_chunk=attn_chunk)
        if remat:
            inner = jax.checkpoint(inner)
        return inner(x), None

    x, _ = jax.lax.scan(seg_step, x, params["segments"])
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params["head"], params["embed"], x, False)
    return logits, jnp.zeros((), jnp.float32)


def init_zamba_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
                     window: int = 0, dtype=jnp.bfloat16) -> Dict:
    n_seg, per = zamba_segments(cfg)
    n_slots = min(window, cache_len) if window else cache_len
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    st = ssm_lib.init_mamba2_state(cfg, batch)
    return {
        "mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (n_seg, per) + x.shape).astype(x.dtype).copy(), st),
        "shared_kv": {
            "k": jnp.zeros((n_seg, batch, n_slots, Hkv, D), dtype),
            "v": jnp.zeros((n_seg, batch, n_slots, Hkv, D), dtype),
        },
        "slot_positions": -jnp.ones((batch, n_slots), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def zamba_decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                      cfg: ArchConfig, *, window: int = 0,
                      compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    B = tokens.shape[0]
    pos = cache["pos"]
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)

    n_slots = cache["shared_kv"]["k"].shape[2]
    slot = pos % n_slots
    bidx = jnp.arange(B)
    slot_positions = cache["slot_positions"].at[bidx, slot].set(pos)

    def mamba_step(x, inp):
        lp, st = inp
        xn = apply_norm(cfg.norm_type, lp["norm"], x)
        y, new_st = ssm_lib.apply_mamba2(lp["mamba"], xn, cfg, state=st)
        return x + y, new_st

    def seg_step(x, inp):
        seg, seg_state, kv = inp
        x, new_states = jax.lax.scan(mamba_step, x, (seg, seg_state))
        # shared attention with this segment-application's own KV cache
        sp = params["shared"]
        xn = apply_norm(cfg.norm_type, sp["attn_norm"], x)
        q, k, v = layers.project_qkv(sp["attn"], xn, pos[:, None], cfg)
        new_k = kv["k"].at[bidx, slot].set(k[:, 0].astype(kv["k"].dtype))
        new_v = kv["v"].at[bidx, slot].set(v[:, 0].astype(kv["v"].dtype))
        a = layers.decode_attention(q, new_k, new_v, slot_positions, pos,
                                    window=window)
        x = x + layers.project_out(sp["attn"], a, cfg)
        xm = apply_norm(cfg.norm_type, sp["mlp_norm"], x)
        x = x + layers.apply_mlp(sp["mlp"], xm, cfg)
        return x, (new_states, {"k": new_k, "v": new_v})

    x, (new_mamba, new_kv) = jax.lax.scan(
        seg_step, x,
        (params["segments"], cache["mamba"], cache["shared_kv"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params["head"], params["embed"], x, False)
    return logits, {
        "mamba": new_mamba,
        "shared_kv": new_kv,
        "slot_positions": slot_positions,
        "pos": pos + 1,
    }


# ===========================================================================
# xLSTM
# ===========================================================================
def xlstm_segments(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.xlstm.slstm_every
    assert per and cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1   # (n_segments, mlstm per segment)


def init_xlstm_params(key, cfg: ArchConfig) -> Dict:
    n_seg, n_ml = xlstm_segments(cfg)
    keys = split_keys(key, 2 + cfg.n_layers)
    p = {
        "embed": layers.embedding_params(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": norm_params(cfg.norm_type, cfg.d_model),
        "head": layers.lm_head_params(keys[1], cfg.d_model, cfg.vocab_size),
    }
    ki = 2
    mls, sls = [], []
    for _s in range(n_seg):
        seg = []
        for _l in range(n_ml):
            seg.append(xlstm_lib.mlstm_params(keys[ki], cfg)); ki += 1
        mls.append(_stack(seg))
        sls.append(xlstm_lib.slstm_params(keys[ki], cfg)); ki += 1
    p["mlstm"] = _stack(mls)     # (n_seg, n_ml, ...)
    p["slstm"] = _stack(sls)     # (n_seg, ...)
    return p


def xlstm_forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
                  window: int = 0, compute_dtype=jnp.bfloat16,
                  attn_chunk: int = 512, remat: bool = True,
                  extra_embeds=None) -> Tuple[jax.Array, jax.Array]:
    del window, attn_chunk
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)

    def ml_step(x, lp):
        y, _ = xlstm_lib.apply_mlstm_block(lp, x, cfg)
        return y, None

    def seg_step(x, inp):
        mseg, sp = inp
        def inner(x_):
            h, _ = jax.lax.scan(ml_step, x_, mseg)
            h, _ = xlstm_lib.apply_slstm_block(sp, h, cfg)
            return h
        if remat:
            inner = jax.checkpoint(inner)
        return inner(x), None

    x, _ = jax.lax.scan(seg_step, x, (params["mlstm"], params["slstm"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params["head"], params["embed"], x, False)
    return logits, jnp.zeros((), jnp.float32)


def init_xlstm_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
                     window: int = 0, dtype=jnp.bfloat16) -> Dict:
    del cache_len, window, dtype
    n_seg, n_ml = xlstm_segments(cfg)
    ml = xlstm_lib.init_mlstm_state(cfg, batch)
    sl = xlstm_lib.init_slstm_state(cfg, batch)
    return {
        "mlstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_seg, n_ml) + x.shape).copy(), ml),
        "slstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_seg,) + x.shape).copy(), sl),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def xlstm_decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                      cfg: ArchConfig, *, window: int = 0,
                      compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    del window
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)

    def ml_step(x, inp):
        lp, st = inp
        y, new_st = xlstm_lib.apply_mlstm_block(lp, x, cfg, state=st)
        return y, new_st

    def seg_step(x, inp):
        mseg, sp, mstate, sstate = inp
        x, new_m = jax.lax.scan(ml_step, x, (mseg, mstate))
        x, new_s = xlstm_lib.apply_slstm_block(sp, x, cfg, state=sstate)
        return x, (new_m, new_s)

    x, (new_ml, new_sl) = jax.lax.scan(
        seg_step, x,
        (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params["head"], params["embed"], x, False)
    return logits, {"mlstm": new_ml, "slstm": new_sl, "pos": cache["pos"] + 1}
