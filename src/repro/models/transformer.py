"""Generic decoder-only transformer LM (dense + MoE), scan-over-layers.

Layer parameters are stacked along a leading layer axis and iterated with
``jax.lax.scan`` so the HLO stays compact for 94-layer configs.  MoE configs
with ``first_dense_layers`` unroll those leading layers separately and scan
the homogeneous MoE remainder.

Step kinds:
  * ``forward``      — (B, S) tokens -> (B, S, vocab) logits  (train/prefill)
  * ``decode_step``  — (B, 1) token + KV cache -> logits + updated cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, moe as moe_lib
from repro.models.common import apply_norm, norm_params, split_keys

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def _block_params(key, cfg: ArchConfig, *, use_moe: bool) -> Dict:
    k1, k2 = split_keys(key, 2)
    p = {
        "attn_norm": norm_params(cfg.norm_type, cfg.d_model, cfg.use_bias),
        "attn": layers.attention_params(k1, cfg),
    }
    if not cfg.parallel_block:
        p["mlp_norm"] = norm_params(cfg.norm_type, cfg.d_model, cfg.use_bias)
    if use_moe:
        p["moe"] = moe_lib.moe_params(k2, cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.first_dense_layers:
            d_ff = cfg.moe.dense_d_ff or cfg.moe.d_expert
        p["mlp"] = layers.mlp_params(k2, cfg, d_ff=d_ff)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ArchConfig) -> Dict:
    n_dense_head = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense_head
    keys = split_keys(key, cfg.n_layers + 3)

    p: Dict[str, PyTree] = {
        "embed": layers.embedding_params(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": norm_params(cfg.norm_type, cfg.d_model, cfg.use_bias),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.lm_head_params(keys[1], cfg.d_model, cfg.vocab_size)

    if n_dense_head:
        p["head_blocks"] = [
            _block_params(keys[2 + i], cfg, use_moe=False)
            for i in range(n_dense_head)
        ]
    p["blocks"] = _stack([
        _block_params(keys[2 + n_dense_head + i], cfg,
                      use_moe=cfg.moe is not None)
        for i in range(n_scan)
    ])
    return p


# ---------------------------------------------------------------------------
# int8 paged-pool quantization (tiered KV, docs/ARCHITECTURE.md §8)
# ---------------------------------------------------------------------------
def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of K/V vectors: per (token, kv-head)
    scale ``amax(|x|, axis=-1) / 127`` so every head-dim row maps onto the
    full int8 range.  Scales are stored in block-granular pools alongside
    the int8 K/V pools (same ``.at[blk, off]`` scatter), which keeps the
    write path incremental — a true per-block amax would need re-reading
    and re-quantizing the whole block on every appended token.  The
    (values, scale) pair roundtrips bit-exactly through host swap-out /
    swap-in: dequantization ``int8 * scale`` is a pure function of the
    stored bytes."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127.0, 127.0)
    return q.astype(jnp.int8), scale


def _write_kv_pool(cache_l: Dict, k: jax.Array, v: jax.Array,
                   blk: jax.Array, off: jax.Array) -> Dict:
    """Scatter a chunk's K/V into the paged pools at ``(blk, off)``.
    fp pools store ``k``/``v`` cast to the pool dtype; int8 pools (marked
    by the ``k_scale`` pool) quantize on write and scatter the per-slot
    scales through the same indices."""
    if "k_scale" in cache_l:
        qk, ks = _quantize_kv(k)
        qv, vs = _quantize_kv(v)
        return {
            "k": cache_l["k"].at[blk, off].set(qk),
            "v": cache_l["v"].at[blk, off].set(qv),
            "k_scale": cache_l["k_scale"].at[blk, off].set(ks),
            "v_scale": cache_l["v_scale"].at[blk, off].set(vs),
        }
    return {
        "k": cache_l["k"].at[blk, off].set(k.astype(cache_l["k"].dtype)),
        "v": cache_l["v"].at[blk, off].set(v.astype(cache_l["v"].dtype)),
    }


def _pool_scales(cache_l: Dict) -> Dict:
    """kwargs forwarding a pool's dequant scales to the attention ops
    (empty for fp pools)."""
    if "k_scale" in cache_l:
        return {"k_scale": cache_l["k_scale"], "v_scale": cache_l["v_scale"]}
    return {}


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_block(bp: Dict, x: jax.Array, positions: jax.Array,
                 cfg: ArchConfig, *, window: int,
                 attn_chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux)."""
    xn = apply_norm(cfg.norm_type, bp["attn_norm"], x)
    q, k, v = layers.project_qkv(bp["attn"], xn, positions, cfg)
    attn = layers.causal_attention(q, k, v, window=window, chunk=attn_chunk)
    attn = layers.project_out(bp["attn"], attn, cfg)

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        # cohere-style: one shared norm, attn and mlp both from xn
        mlp_out = layers.apply_mlp(bp["mlp"], xn, cfg)
        return x + attn + mlp_out, aux

    x = x + attn
    xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
    if "moe" in bp:
        mlp_out, aux = moe_lib.apply_moe(bp["moe"], xm, cfg)
    else:
        mlp_out = layers.apply_mlp(bp["mlp"], xm, cfg)
    return x + mlp_out, aux


def _apply_block_paged(bp: Dict, x: jax.Array, cache_l: Dict,
                       block_tables: jax.Array, pos: jax.Array,
                       q_lens: Optional[jax.Array], cfg: ArchConfig, *,
                       window: int,
                       use_kernel: Optional[bool] = None
                       ) -> Tuple[jax.Array, Dict]:
    """Process a chunk of C tokens per lane through one block against the
    paged KV pool — the unified prefill/decode path (C = 1 is plain
    decode).

    cache_l: {"k","v"} (num_blocks, block_size, Hkv, D); block_tables
    (B, max_blocks) maps lane-logical blocks to pool slots; pos (B,) is the
    first write position of each lane's chunk; q_lens (B,) the number of
    real tokens in it (None = all C).  Writes past a lane's q_len land on
    the reserved null block 0 — a legal, never-read target — so padded
    lanes and budget-deferred lanes are harmless.
    """
    from repro.kernels import ops as kernel_ops
    B, C = x.shape[:2]
    bs = cache_l["k"].shape[1]
    max_blocks = block_tables.shape[1]
    xn = apply_norm(cfg.norm_type, bp["attn_norm"], x)
    offs = jnp.arange(C)
    positions = pos[:, None] + offs[None, :]                  # (B, C)
    q, k, v = layers.project_qkv(bp["attn"], xn, positions, cfg)
    if q_lens is None:
        q_lens = jnp.full((B,), C, jnp.int32)
    valid = offs[None, :] < q_lens[:, None]                   # (B, C)
    bidx = jnp.arange(B)[:, None]
    lblk = jnp.minimum(positions // bs, max_blocks - 1)
    blk = jnp.where(valid, block_tables[bidx, lblk], 0)       # 0: null block
    off = jnp.where(valid, positions % bs, 0)
    new_cl = _write_kv_pool(cache_l, k, v, blk, off)
    attn = kernel_ops.paged_attention_chunk(q, new_cl["k"], new_cl["v"],
                                            block_tables,
                                            pos, q_lens, window=window,
                                            use_kernel=use_kernel,
                                            **_pool_scales(new_cl))
    attn = layers.project_out(bp["attn"], attn, cfg)

    if cfg.parallel_block:
        mlp_out = layers.apply_mlp(bp["mlp"], xn, cfg)
        return x + attn + mlp_out, new_cl

    x = x + attn
    xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
    if "moe" in bp:
        mlp_out, _ = moe_lib.apply_moe(bp["moe"], xm, cfg)
    else:
        mlp_out = layers.apply_mlp(bp["mlp"], xm, cfg)
    return x + mlp_out, new_cl


def _apply_block_ragged(bp: Dict, x: jax.Array, cache_l: Dict,
                        token_tables: Optional[jax.Array],
                        token_pos: jax.Array, slot_mapping: jax.Array,
                        tile_spec, cfg: ArchConfig, *,
                        window: int,
                        use_kernel: Optional[bool] = None
                        ) -> Tuple[jax.Array, Dict]:
    """Process one flat stream of T tokens (mixed prefill chunks and
    decodes from many lanes, no per-lane rectangle) through one block
    against the paged KV pool.

    x: (1, T, d) — the whole mixed batch as one "sequence"; RoPE is
    anchored per token by ``token_pos`` (T,).  Each token's K/V is
    scattered straight into its physical pool slot ``slot_mapping[t]``
    (= block_id * block_size + offset); padding tokens carry slot 0 — the
    reserved null block, a legal never-trusted target.

    The attention read has two grids: with ``tile_spec`` — a (block_tables,
    tile_meta, row_tile, tile) tuple from the engine's
    :class:`~repro.serving.batch.TileMap` — q rows are tiled by segment and
    each lane's KV blocks are read once per tile; with ``tile_spec=None``
    the per-token baseline gathers through ``token_tables`` (T, max_blocks)
    once per token.
    """
    from repro.kernels import ops as kernel_ops
    bs = cache_l["k"].shape[1]
    xn = apply_norm(cfg.norm_type, bp["attn_norm"], x)
    q, k, v = layers.project_qkv(bp["attn"], xn, token_pos[None, :], cfg)
    blk = slot_mapping // bs
    off = slot_mapping % bs
    new_cl = _write_kv_pool(cache_l, k[0], v[0], blk, off)
    if tile_spec is not None:
        tables, tile_meta, row_tile, tile = tile_spec
        attn = kernel_ops.paged_attention_ragged_tiled(
            q[0], new_cl["k"], new_cl["v"], tables, tile_meta, row_tile,
            tile=tile, window=window, use_kernel=use_kernel,
            **_pool_scales(new_cl))
    else:
        attn = kernel_ops.paged_attention_ragged(q[0], new_cl["k"],
                                                 new_cl["v"],
                                                 token_tables, token_pos,
                                                 window=window,
                                                 use_kernel=use_kernel,
                                                 **_pool_scales(new_cl))
    attn = layers.project_out(bp["attn"], attn[None], cfg)

    if cfg.parallel_block:
        mlp_out = layers.apply_mlp(bp["mlp"], xn, cfg)
        return x + attn + mlp_out, new_cl

    x = x + attn
    xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
    if "moe" in bp:
        mlp_out, _ = moe_lib.apply_moe(bp["moe"], xm, cfg)
    else:
        mlp_out = layers.apply_mlp(bp["mlp"], xm, cfg)
    return x + mlp_out, new_cl


def _apply_block_decode(bp: Dict, x: jax.Array, cache_l: Dict,
                        slot_positions: jax.Array, pos: jax.Array,
                        cfg: ArchConfig, *, window: int
                        ) -> Tuple[jax.Array, Dict]:
    """Decode one token through one block; cache_l: {"k","v"} (B,S,Hkv,D)."""
    B = x.shape[0]
    xn = apply_norm(cfg.norm_type, bp["attn_norm"], x)
    q, k, v = layers.project_qkv(bp["attn"], xn, pos[:, None], cfg)
    # write new k/v into the cache slot (rolling: slot = pos % n_slots)
    n_slots = cache_l["k"].shape[1]
    slot = (pos % n_slots)
    bidx = jnp.arange(B)
    new_k = cache_l["k"].at[bidx, slot].set(k[:, 0].astype(cache_l["k"].dtype))
    new_v = cache_l["v"].at[bidx, slot].set(v[:, 0].astype(cache_l["v"].dtype))
    attn = layers.decode_attention(q, new_k, new_v, slot_positions, pos,
                                   window=window)
    attn = layers.project_out(bp["attn"], attn, cfg)

    if cfg.parallel_block:
        mlp_out = layers.apply_mlp(bp["mlp"], xn, cfg)
        return x + attn + mlp_out, {"k": new_k, "v": new_v}

    x = x + attn
    xm = apply_norm(cfg.norm_type, bp["mlp_norm"], x)
    if "moe" in bp:
        mlp_out, _ = moe_lib.apply_moe(bp["moe"], xm, cfg)
    else:
        mlp_out = layers.apply_mlp(bp["mlp"], xm, cfg)
    return x + mlp_out, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
            window: int = 0, extra_embeds: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> (logits (B,S,vocab) fp32, moe_aux scalar).

    ``extra_embeds`` (B, S_extra, d_model): already-projected frontend
    embeddings prepended to the token embeddings (VLM path).
    """
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    if extra_embeds is not None:
        # §Perf-4: constrain BOTH concat operands before concatenating —
        # an unconstrained extra_embeds makes GSPMD resolve the concat at
        # a replicated layout, all-gathering the already-batch-committed
        # token embeddings first and re-slicing after (llava train was
        # 22 s of collectives from this one op); with both inputs pinned
        # the concat is layout-preserving and emits no collective
        from repro.models.common import constrain
        x = constrain(x, "batch", None, None)
        extra = constrain(extra_embeds.astype(compute_dtype),
                          "batch", None, None)
        x = jnp.concatenate([extra, x], axis=1)
        x = constrain(x, "batch", None, None)
    S = x.shape[1]
    positions = jnp.arange(S)

    aux_total = jnp.zeros((), jnp.float32)

    for bp in params.get("head_blocks", []):
        x, aux = _apply_block(bp, x, positions, cfg, window=window,
                              attn_chunk=attn_chunk)
        aux_total = aux_total + aux

    def block_call(bp_, x_):
        return _apply_block(bp_, x_, positions, cfg, window=window,
                            attn_chunk=attn_chunk)

    if remat:
        # activation checkpointing: recompute block internals in backward
        block_call = jax.checkpoint(block_call)

    def layer_step(carry, bp):
        x, aux_acc = carry
        x_new, aux = block_call(bp, x)
        return (x_new, aux_acc + aux), None

    (x, aux_total), _ = jax.lax.scan(layer_step, (x, aux_total),
                                     params["blocks"])
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params.get("head"), params["embed"], x,
                              cfg.tie_embeddings)
    return logits, aux_total


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, labels: jax.Array,
            aux: jax.Array = None, aux_weight: float = 0.0,
            z_loss: float = 1e-4) -> Tuple[jax.Array, Dict]:
    """Cross-entropy with label -1 = ignore.  logits (B,S,V) fp32."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    zl = z_loss * ((logz * mask) ** 2).sum() / denom
    loss = ce + zl
    metrics = {"ce": ce, "z_loss": zl, "tokens": mask.sum()}
    if aux is not None and aux_weight:
        loss = loss + aux_weight * aux
        metrics["moe_aux"] = aux
    return loss, metrics


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *,
            window: int = 0, attn_chunk: int = 512,
            remat: bool = True) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg, window=window,
                          extra_embeds=batch.get("extra_embeds"),
                          attn_chunk=attn_chunk, remat=remat)
    labels = batch["labels"]
    if "extra_embeds" in batch and batch["extra_embeds"] is not None:
        # frontend positions carry no LM loss
        pad = -jnp.ones(batch["extra_embeds"].shape[:2], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    aw = cfg.moe.router_aux_weight if cfg.moe else 0.0
    return lm_loss(logits, labels, aux, aw)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: int = 0, dtype=jnp.bfloat16) -> Dict:
    """KV cache.  With a sliding window the cache is a rolling buffer of
    ``min(window, cache_len)`` slots — decisive for long_500k memory."""
    n_slots = min(window, cache_len) if window else cache_len
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    n_dense_head = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense_head

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, n_slots, Hkv, D), dtype),
            "v": jnp.zeros((n, batch, n_slots, Hkv, D), dtype),
        }

    cache = {
        "scan": kv(n_scan),
        "slot_positions": -jnp.ones((batch, n_slots), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if n_dense_head:
        cache["head"] = kv(n_dense_head)
    return cache


def init_paged_cache(cfg: ArchConfig, n_lanes: int, *, num_blocks: int,
                     block_size: int, max_blocks_per_lane: int,
                     dtype=jnp.bfloat16) -> Dict:
    """Paged KV cache: per-layer physical pools shared by all lanes.

    Unlike :func:`init_cache` there is no per-lane dense slab — memory is
    the pool (num_blocks x block_size tokens per layer) and lanes borrow
    blocks through their ``block_tables`` row.  Block 0 is the engine's
    reserved null block.

    ``dtype=jnp.int8`` selects the quantized storage mode (tiered KV,
    docs/ARCHITECTURE.md §8): K/V pools store int8 values and each gains a
    float32 ``{k,v}_scale`` pool of shape ``(n, num_blocks, block_size,
    Hkv)`` — one symmetric scale per (block, slot, kv-head), written by
    the same scatter as the values and multiplied back in on the
    attention read.  KV read/write bandwidth drops ~4x vs fp32 pools
    (~2x vs bf16) at ~0.4% relative reconstruction error.
    """
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    n_dense_head = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense_head
    quantized = jnp.issubdtype(jnp.dtype(dtype), jnp.integer)

    def kv(n):
        pool = {
            "k": jnp.zeros((n, num_blocks, block_size, Hkv, D), dtype),
            "v": jnp.zeros((n, num_blocks, block_size, Hkv, D), dtype),
        }
        if quantized:
            shape = (n, num_blocks, block_size, Hkv)
            pool["k_scale"] = jnp.zeros(shape, jnp.float32)
            pool["v_scale"] = jnp.zeros(shape, jnp.float32)
        return pool

    cache = {
        "scan": kv(n_scan),
        "block_tables": jnp.zeros((n_lanes, max_blocks_per_lane), jnp.int32),
        "pos": jnp.zeros((n_lanes,), jnp.int32),
    }
    if n_dense_head:
        cache["head"] = kv(n_dense_head)
    return cache


def paged_step(params: Dict, cache: Dict, tokens: jax.Array,
               cfg: ArchConfig, *, window: int = 0,
               compute_dtype=jnp.bfloat16,
               use_kernel: Optional[bool] = None) -> Tuple[jax.Array, Dict]:
    """tokens (B,C) -> (logits (B,C,V), new cache) — the unified
    prefill/decode step over the paged KV pool.  A lane's chunk can be a
    multi-token prefill slice, a single decode token (C = 1), or padding;
    prefill and decode therefore share one compiled path per chunk width.

    ``use_kernel`` pins the attention dispatch (None = per-backend
    default); a mesh-sharded engine passes False so the step lowers to
    the GSPMD-partitionable reference read on every shard.

    ``cache["pos"]`` is the per-lane position of the chunk's first token
    (== tokens already in that lane's KV) and anchors RoPE;
    ``cache["q_lens"]`` (optional, (B,)) is the number of real tokens in
    each lane's chunk — absent means all C.  The serving engine overwrites
    ``pos``/``q_lens``/``block_tables`` before every step as lanes turn
    over, so the advanced ``pos`` carried out below only services the
    single-sequence debug path.
    """
    pos = cache["pos"]
    tables = cache["block_tables"]
    q_lens = cache.get("q_lens")
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)

    new_head = []
    for i, bp in enumerate(params.get("head_blocks", [])):
        cl = {name: arr[i] for name, arr in cache["head"].items()}
        x, ncl = _apply_block_paged(bp, x, cl, tables, pos, q_lens, cfg,
                                    window=window, use_kernel=use_kernel)
        new_head.append(ncl)

    def layer_step(x, inp):
        bp, cl = inp
        x, ncl = _apply_block_paged(bp, x, cl, tables, pos, q_lens, cfg,
                                    window=window, use_kernel=use_kernel)
        return x, ncl

    x, new_scan = jax.lax.scan(layer_step, x,
                               (params["blocks"], cache["scan"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params.get("head"), params["embed"], x,
                              cfg.tie_embeddings)

    new_cache = {
        "scan": new_scan,
        "block_tables": tables,
        "pos": pos + (tokens.shape[1] if q_lens is None else q_lens),
    }
    if q_lens is not None:
        new_cache["q_lens"] = q_lens
    if new_head:
        new_cache["head"] = {
            name: jnp.stack([c[name] for c in new_head])
            for name in new_head[0]
        }
    return logits, new_cache


def ragged_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ArchConfig, *, window: int = 0, tile: int = 16,
                compute_dtype=jnp.bfloat16,
                use_kernel: Optional[bool] = None) -> Tuple[jax.Array, Dict]:
    """tokens (T,) -> (logits (T, V), new cache) — the ragged flat-token
    serving step.  T is one pow2-bucketed stream of *all* scheduled tokens
    this engine iteration (multi-token prefill chunks and single decode
    tokens back to back, each request a contiguous segment) — no
    ``(lanes, chunk_width)`` rectangle is ever materialized, so one lane
    prefilling a long chunk no longer pads every decoding lane out to the
    chunk width.

    Per-token metadata rides in the cache and is overwritten by the engine
    before every step:
      * ``token_lane``   (T,) — owning engine lane (selects the block-table
        row for the attention read);
      * ``token_pos``    (T,) — the token's absolute position in its own
        sequence (anchors RoPE and the causal bound);
      * ``slot_mapping`` (T,) — physical KV pool slot the token writes,
        ``block_id * block_size + offset`` (0 = reserved null block for
        padding tokens);
      * ``block_tables`` (n_lanes, max_blocks) — per-lane physical block
        rows.

    When the engine also ships segment-tile metadata (the default):
      * ``tile_meta`` (5, n_tiles) int32 + ``row_tile`` (T,) — the
        :class:`~repro.serving.batch.TileMap` arrays (``tile`` static rows
        per q window) — the attention read runs the segment-tiled grid,
        sweeping each lane's KV blocks once per q-tile instead of once per
        token.  Without them the per-token grid is the measured baseline.

    The returned logits cover EVERY row of the stream, not just each
    lane's final segment row — the speculative-decode verification
    contract (see :class:`~repro.models.api.ModelAPI`): row t is the
    next-token distribution after the stream's token t, so a decode
    segment carrying drafted tokens at consecutive positions yields the
    model's own greedy continuation at every draft slot in one step.

    Under a mesh-sharded engine nothing here changes: the metadata above
    arrives replicated, the KV pools arrive kv-head-sharded, and GSPMD
    partitions the step from those input shardings (``use_kernel=False``
    keeps the attention read on the partitionable reference path).  The
    flat stream stays replicated — per-token work is head/expert
    parallel, not token-parallel.
    """
    token_pos = cache["token_pos"]
    token_lane = cache["token_lane"]
    slot_mapping = cache["slot_mapping"]
    tables = cache["block_tables"]
    if "tile_meta" in cache:
        tile_spec = (tables, cache["tile_meta"], cache["row_tile"], tile)
        token_tables = None            # tiled read never gathers per token
    else:
        tile_spec = None
        token_tables = tables[token_lane]                 # (T, max_blocks)
    x = layers.embed_tokens(params["embed"], tokens[None], compute_dtype)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)

    new_head = []
    for i, bp in enumerate(params.get("head_blocks", [])):
        cl = {name: arr[i] for name, arr in cache["head"].items()}
        x, ncl = _apply_block_ragged(bp, x, cl, token_tables, token_pos,
                                     slot_mapping, tile_spec, cfg,
                                     window=window, use_kernel=use_kernel)
        new_head.append(ncl)

    def layer_step(x, inp):
        bp, cl = inp
        x, ncl = _apply_block_ragged(bp, x, cl, token_tables, token_pos,
                                     slot_mapping, tile_spec, cfg,
                                     window=window, use_kernel=use_kernel)
        return x, ncl

    x, new_scan = jax.lax.scan(layer_step, x,
                               (params["blocks"], cache["scan"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params.get("head"), params["embed"], x,
                              cfg.tie_embeddings)

    new_cache = {
        "scan": new_scan,
        "block_tables": tables,
        "token_lane": token_lane,
        "token_pos": token_pos,
        "slot_mapping": slot_mapping,
    }
    if "tile_meta" in cache:
        new_cache["tile_meta"] = cache["tile_meta"]
        new_cache["row_tile"] = cache["row_tile"]
    if new_head:
        new_cache["head"] = {
            name: jnp.stack([c[name] for c in new_head])
            for name in new_head[0]
        }
    return logits[0], new_cache


def paged_decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                      cfg: ArchConfig, *, window: int = 0,
                      compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    """tokens (B,1) -> (logits (B,1,V), new cache) — kept as the q_len = 1
    special case of :func:`paged_step` for the single-sequence debug path
    and API compatibility."""
    return paged_step(params, cache, tokens, cfg, window=window,
                      compute_dtype=compute_dtype)


def decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                cfg: ArchConfig, *, window: int = 0,
                compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    """tokens (B,1) -> (logits (B,1,V), new cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = layers.embed_tokens(params["embed"], tokens, compute_dtype)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)

    n_slots = cache["scan"]["k"].shape[2]
    slot = pos % n_slots
    slot_positions = cache["slot_positions"].at[jnp.arange(B), slot].set(pos)

    new_head = []
    for i, bp in enumerate(params.get("head_blocks", [])):
        cl = {"k": cache["head"]["k"][i], "v": cache["head"]["v"][i]}
        x, ncl = _apply_block_decode(bp, x, cl, slot_positions, pos, cfg,
                                     window=window)
        new_head.append(ncl)

    def layer_step(x, inp):
        bp, cl = inp
        x, ncl = _apply_block_decode(bp, x, cl, slot_positions, pos, cfg,
                                     window=window)
        return x, ncl

    x, new_scan = jax.lax.scan(layer_step, x,
                               (params["blocks"], cache["scan"]))
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = layers.lm_logits(params.get("head"), params["embed"], x,
                              cfg.tie_embeddings)

    new_cache = {
        "scan": new_scan,
        "slot_positions": slot_positions,
        "pos": pos + 1,
    }
    if new_head:
        new_cache["head"] = {
            "k": jnp.stack([c["k"] for c in new_head]),
            "v": jnp.stack([c["v"] for c in new_head]),
        }
    return logits, new_cache
