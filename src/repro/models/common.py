"""Shared model utilities: dtype policy, initializers, norms, embeddings.

All models are functional: ``init(key, cfg) -> params`` pytrees of plain dicts
and pure ``apply`` functions.  Compute runs in ``Policy.compute_dtype``
(bf16 by default) with fp32 master params and fp32 softmax/norm accumulators.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def abstract_mesh():
    """``jax.sharding.get_abstract_mesh()`` on jax >= 0.5; None on older
    jax (no explicit-sharding mesh API — in-model sharding constraints
    degrade to no-ops, which is correct on a single device)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    return get() if get is not None else None


@jax.custom_vjp
def optimization_barrier(x: jax.Array) -> jax.Array:
    """``jax.lax.optimization_barrier`` that differentiates on every jax
    version (jax < 0.5 has no differentiation rule for the primitive; the
    custom identity VJP sidesteps it — the barrier is semantically the
    identity, only a scheduling fence)."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return optimization_barrier(x), None


def _barrier_bwd(_, g):
    return (g,)


optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def cast_compute(self, tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            tree,
        )


DEFAULT_POLICY = Policy()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape: Sequence[int], *, in_axis: int = 0,
               scale: float = 1.0, dtype=jnp.float32) -> jax.Array:
    """Variance-scaling (fan-in) truncated-normal initializer."""
    fan_in = shape[in_axis]
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape: Sequence[int], *, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_params(d: int) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((d,), jnp.float32)}


def layernorm_params(d: int, use_bias: bool = True) -> Dict[str, jax.Array]:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if use_bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_params(kind: str, d: int, use_bias: bool = True) -> Dict[str, jax.Array]:
    if kind == "rmsnorm":
        return rmsnorm_params(d)
    return layernorm_params(d, use_bias)


def apply_norm(kind: str, p: Dict[str, jax.Array], x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    """Normalize in fp32, return in x.dtype."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
        if "bias" in p:
            out = out + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# In-model sharding constraints (no-op without an active mesh)
# ---------------------------------------------------------------------------
def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """Constrain ``x`` along logical dims: "batch" | "model" | None.

    "batch" expands to the mesh's ("pod","data") axes when present.  Every
    assignment is divisibility-checked; without an active mesh (CPU tests)
    this is a no-op, so model code can call it unconditionally.
    """
    mesh = abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for size, d in zip(x.shape, dims):
        choice = None
        if d == "batch" and batch_axes:
            for k in range(len(batch_axes), 0, -1):
                axes = batch_axes[-k:]
                n = 1
                for a in axes:
                    n *= sizes[a]
                if size % n == 0:
                    choice = axes if len(axes) > 1 else axes[0]
                    break
        elif d == "model" and "model" in names:
            if size % sizes["model"] == 0:
                choice = "model"
        spec.append(choice)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Parameter counting
# ---------------------------------------------------------------------------
def count_params(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))
