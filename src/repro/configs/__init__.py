"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    FrontendStub,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
    available_archs,
    get_config,
)
from repro.configs.shapes import (  # noqa: F401
    SHAPES,
    InputShape,
    all_shapes,
    get_shape,
    smoke_shape,
)

# registration side-effects
from repro.configs import (  # noqa: F401
    command_r_35b,
    deepseek_moe_16b,
    gemma_7b,
    llava_next_mistral_7b,
    moonshot_v1_16b_a3b,
    qwen3_moe_235b_a22b,
    starcoder2_7b,
    whisper_base,
    xlstm_1p3b,
    zamba2_2p7b,
)
from repro.configs.paper_models import (  # noqa: F401
    BraggNNConfig,
    CookieNetAEConfig,
)

ASSIGNED_ARCHS = (
    "zamba2-2.7b",
    "moonshot-v1-16b-a3b",
    "qwen3-moe-235b-a22b",
    "starcoder2-7b",
    "deepseek-moe-16b",
    "xlstm-1.3b",
    "whisper-base",
    "command-r-35b",
    "gemma-7b",
    "llava-next-mistral-7b",
)
