"""gemma-7b — GeGLU, head_dim=256 (16H x 256 = 4096 != d_model) [arXiv:2403.08295]."""
from repro.configs.base import ArchConfig, register


@register("gemma-7b")
def gemma_7b() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b",
        family="dense",
        source="arXiv:2403.08295",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        tie_embeddings=True,
        scale_embeddings=True,
        long_context_window=4096,   # beyond-card SWA variant for long_500k
        mlp_type="geglu",
        norm_type="rmsnorm",
    )
