"""llava-next-mistral-7b — anyres tiling VLM [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B language backbone (native sliding-window 4096).  The vision tower
(CLIP ViT-L/14-336) + projector is a STUB per the assignment carve-out:
``input_specs()`` supplies precomputed patch embeddings.  anyres tiling: up to
4 tiles + 1 base image, 576 patches each = 2880 image tokens, d_embed=1024
(CLIP hidden), projected to d_model by a real learned 2-layer MLP projector.
"""
from repro.configs.base import ArchConfig, FrontendStub, register


@register("llava-next-mistral-7b")
def llava_next_mistral_7b() -> ArchConfig:
    return ArchConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1_000_000.0,
        sliding_window=4096,        # mistral native SWA -> long_500k runs
        frontend=FrontendStub(kind="image_patches", n_tokens=2880, d_embed=1024),
        mlp_type="swiglu",
        norm_type="rmsnorm",
    )
