"""starcoder2-7b — GQA, RoPE, native sliding-window 4096 [arXiv:2402.19173]."""
from repro.configs.base import ArchConfig, register


@register("starcoder2-7b")
def starcoder2_7b() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        source="arXiv:2402.19173",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        rope_theta=1_000_000.0,
        sliding_window=4096,       # native SWA -> long_500k runs as-is
        mlp_type="gelu",           # non-gated c_fc/c_proj MLP
        norm_type="layernorm",
        use_bias=True,
    )
