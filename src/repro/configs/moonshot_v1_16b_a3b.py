"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — DeepSeek-style fine-grained MoE.

[hf:moonshotai/Moonlight-16B-A3B] 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, 64 routed experts top-6 + 2 shared, first layer dense.
"""
from repro.configs.base import ArchConfig, MoEConfig, register


@register("moonshot-v1-16b-a3b")
def moonshot_v1_16b_a3b() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        source="hf:moonshotai/Moonlight-16B-A3B",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        moe=MoEConfig(
            n_experts=64,
            experts_per_token=6,
            d_expert=1408,
            n_shared_experts=2,
            first_dense_layers=1,
            dense_d_ff=11264,  # 8 * 1408, DeepSeek-style wide first dense layer
        ),
        long_context_window=4096,  # SWA long-context variant (beyond paper card)
        mlp_type="swiglu",
        norm_type="rmsnorm",
    )
