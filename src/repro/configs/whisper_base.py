"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.  The mel-spectrogram +
conv frontend is a STUB per the assignment carve-out: ``input_specs()``
supplies precomputed frame embeddings (1500 x 512 after the conv stride-2).

Shape policy: the decoder's learned positions cap at 448; decode shapes are
lowered structurally with the assigned cache length.  ``long_500k`` is SKIPPED
(out of family for a 448-position decoder; see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, FrontendStub, register


@register("whisper-base")
def whisper_base() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=6,                 # decoder layers
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        is_encoder_decoder=True,
        n_encoder_layers=6,
        encoder_positions=1500,
        max_decoder_positions=448,
        frontend=FrontendStub(kind="audio_frames", n_tokens=1500, d_embed=512),
        mlp_type="gelu",
        norm_type="layernorm",
        use_bias=True,
        tie_embeddings=True,
        supports_long_context=False,
        long_context_skip_reason=(
            "whisper decoder has 448 learned positions and a fixed 1500-frame "
            "encoder; a 524288-token decode context is out of family"
        ),
    )
