"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936, head_dim=128,
qk-norm, no shared experts.
"""
from repro.configs.base import ArchConfig, MoEConfig, register


@register("qwen3-moe-235b-a22b")
def qwen3_moe_235b_a22b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab_size=151936,
        rope_theta=1_000_000.0,
        qk_norm=True,
        moe=MoEConfig(
            n_experts=128,
            experts_per_token=8,
            d_expert=1536,
            n_shared_experts=0,
            router_aux_weight=0.001,
        ),
        long_context_window=4096,
        mlp_type="swiglu",
        norm_type="rmsnorm",
    )
