"""The four assigned input shapes and their step kinds."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: Dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")


def all_shapes() -> Tuple[InputShape, ...]:
    return tuple(SHAPES.values())


def smoke_shape(kind: str = "train") -> InputShape:
    """Tiny shape for CPU smoke tests."""
    if kind == "train":
        return InputShape("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return InputShape("smoke_prefill", 32, 2, "prefill")
    return InputShape("smoke_decode", 32, 2, "decode")
