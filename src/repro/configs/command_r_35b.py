"""command-r-35b — GQA, no-bias, parallel block [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ArchConfig, register


@register("command-r-35b")
def command_r_35b() -> ArchConfig:
    return ArchConfig(
        name="command-r-35b",
        family="dense",
        source="hf:CohereForAI/c4ai-command-r-v01",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        rope_theta=8_000_000.0,
        parallel_block=True,        # cohere parallel attn+mlp residual block
        tie_embeddings=True,
        long_context_window=4096,   # SWA long-context variant for long_500k
        mlp_type="swiglu",
        norm_type="layernorm",
        use_bias=False,
    )
