"""Configs for the paper's own edge DNNs: BraggNN and CookieNetAE.

These are not part of the assigned-architecture pool; they are the models the
paper actually (re)trains through the workflow (Table 1) and are used by the
end-to-end examples and Table-1 benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BraggNNConfig:
    """BraggNN [arXiv:2008.08198]: 11x11 Bragg-peak patch -> (y, x) center."""

    name: str = "braggnn"
    patch: int = 11
    base_channels: int = 64          # first conv width
    fcsz: tuple = (16, 8, 4, 2)      # fully-connected stack
    imgsz: int = 11

    @property
    def input_shape(self) -> tuple:
        return (self.patch, self.patch, 1)


@dataclass(frozen=True)
class CookieNetAEConfig:
    """CookieNetAE: 16-channel eToF energy-histogram image -> per-channel pdf.

    8 convolution layers, 343,937 trainable parameters (verified by test),
    ReLU activations, MSE loss, Adam lr=1e-3 (paper §5.2).
    """

    name: str = "cookienetae"
    channels: int = 16               # CookieBox eToF channels (image rows)
    bins: int = 128                  # 1 eV energy bins (image cols)

    @property
    def input_shape(self) -> tuple:
        return (self.channels, self.bins, 1)
