"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig, SSMConfig, register


@register("zamba2-2.7b")
def zamba2_2p7b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        source="arXiv:2411.15242",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        # chunk_size=128 keeps the intra-chunk (Q x Q x H) SSD tensors inside
        # per-device HBM budget at train_4k (see DESIGN.md §5)
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                      chunk_size=128),
        hybrid_attn_every=6,          # shared-weight attn block every 6 mamba layers
        long_context_window=4096,     # shared attn runs SWA at 500k (DESIGN.md)
        mlp_type="geglu",
        norm_type="rmsnorm",
        supports_long_context=True,   # SSM backbone is sub-quadratic
    )
