"""Architecture configuration system.

Every assigned architecture is a frozen :class:`ArchConfig` registered under
its public id.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct lowering, no allocation); ``smoke_variant()`` derives the
reduced config (<=2 layers, d_model<=512, <=4 experts) used by CPU smoke
tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds used by hybrid / xLSTM stack layouts.
# ---------------------------------------------------------------------------
ATTN = "attn"          # standard (GQA) attention + MLP transformer block
MAMBA2 = "mamba2"      # Mamba2 SSD block
SLSTM = "slstm"        # xLSTM sLSTM block (scalar memory)
MLSTM = "mlstm"        # xLSTM mLSTM block (matrix memory)
SHARED_ATTN = "shared_attn"  # zamba2-style shared-weight attention block


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (fine-grained DeepSeek style supported)."""

    n_experts: int
    experts_per_token: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared_experts: int = 0     # always-on shared experts (DeepSeek/Moonlight)
    first_dense_layers: int = 0   # leading layers that use a dense MLP instead
    dense_d_ff: int = 0           # FFN width of those dense layers (0 -> d_expert)
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight
    router_jitter: float = 0.0
    capacity_factor: float = 1.25  # expert capacity factor for dropped-token routing
    # "gshard": one-hot dispatch/combine einsums (paper-faithful baseline);
    # "gather": zero-FLOP gather/scatter dispatch (beyond-paper, §Perf-1)
    impl: str = "gshard"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD sub-config."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # SSD head dim -> n_ssm_heads = d_inner // head_dim
    chunk_size: int = 256        # chunked-scan block length
    n_groups: int = 1            # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8         # one sLSTM block per this many blocks (xLSTM[7:1])
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334
    conv1d_kernel: int = 4


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: precomputed embeddings, right shapes only.

    ``kind`` in {"audio_frames", "image_patches"}.  ``n_tokens`` is the number
    of embedding vectors the (stubbed) frontend emits; ``d_embed`` their width
    (projected to d_model by a real learned projection in the backbone).
    """

    kind: str
    n_tokens: int
    d_embed: int


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str                  # citation (arXiv / hf model card)

    # -- core dims ---------------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # -- attention ---------------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full attention
    # sliding window used only for the long_500k shape when the base model is
    # full-attention (beyond-paper long-context variant; see DESIGN.md):
    long_context_window: int = 0
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False        # qwen3-style per-head q/k RMSNorm

    # -- block flavour -----------------------------------------------------
    mlp_type: str = "swiglu"     # swiglu | geglu | gelu (non-gated)
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    use_bias: bool = False
    parallel_block: bool = False  # command-r style parallel attn+mlp
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: multiply embeds by sqrt(d_model)

    # -- sub-family configs --------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # hybrid stack layout: zamba2 applies a shared attention block every k
    # mamba layers (weights tied across applications).
    hybrid_attn_every: int = 0

    # -- encoder-decoder (whisper) ------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_positions: int = 0   # fixed encoder sequence length (1500 whisper)
    max_decoder_positions: int = 0  # 0 = unlimited (rope); whisper: 448 learned

    # -- modality frontend stub ----------------------------------------------
    frontend: Optional[FrontendStub] = None

    # -- shape-support policy -------------------------------------------------
    supports_long_context: bool = True   # can run long_500k (natively or via SWA)
    supports_decode: bool = True
    long_context_skip_reason: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attn_out_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def block_layout(self) -> Tuple[str, ...]:
        """Per-layer block kinds for the full stack (decoder side)."""
        if self.xlstm is not None:
            k = self.xlstm.slstm_every
            return tuple(
                SLSTM if (i % k == k - 1) else MLSTM for i in range(self.n_layers)
            )
        if self.ssm is not None and self.hybrid_attn_every:
            k = self.hybrid_attn_every
            return tuple(
                # a mamba layer, with a shared attn block fused after every k-th
                (MAMBA2 + "+" + SHARED_ATTN) if (i % k == k - 1) else MAMBA2
                for i in range(self.n_layers)
            )
        if self.ssm is not None:
            return tuple(MAMBA2 for _ in range(self.n_layers))
        return tuple(ATTN for _ in range(self.n_layers))

    # ------------------------------------------------------------------
    def smoke_variant(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        d_model = min(self.d_model, 256)
        n_heads = max(1, min(self.n_heads, 4))
        # keep the GQA ratio if possible
        ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
        n_kv = max(1, n_heads // ratio)
        head_dim = 64 if self.head_dim else 0
        updates: Dict[str, object] = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=(
                min(self.long_context_window, 64) if self.long_context_window else 0
            ),
        )
        if self.moe is not None:
            updates["moe"] = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                experts_per_token=min(self.moe.experts_per_token, 2),
                d_expert=min(self.moe.d_expert, 128),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                dense_d_ff=min(self.moe.dense_d_ff, 256) if self.moe.dense_d_ff else 0,
            )
        if self.ssm is not None:
            updates["ssm"] = replace(
                self.ssm,
                d_state=min(self.ssm.d_state, 16),
                head_dim=32,
                chunk_size=32,
            )
        if self.xlstm is not None:
            updates["xlstm"] = replace(self.xlstm, slstm_every=2)
        if self.hybrid_attn_every:
            updates["hybrid_attn_every"] = 2
        if self.is_encoder_decoder:
            updates["n_encoder_layers"] = 2
            updates["encoder_positions"] = min(self.encoder_positions, 64)
            updates["max_decoder_positions"] = (
                min(self.max_decoder_positions, 64) if self.max_decoder_positions else 0
            )
        if self.frontend is not None:
            updates["frontend"] = replace(
                self.frontend,
                n_tokens=min(self.frontend.n_tokens, 16),
                d_embed=min(self.frontend.d_embed, 64),
            )
        return replace(self, **updates)  # type: ignore[arg-type]

    def validate(self) -> None:
        assert self.n_heads % max(1, self.n_kv_heads) == 0, self.name
        assert self.family in {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
        if self.family == "moe":
            assert self.moe is not None
        if self.is_encoder_decoder:
            assert self.n_encoder_layers > 0 and self.encoder_positions > 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _  # noqa: F401

        if name not in _REGISTRY:
            raise KeyError(
                f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
            )
    cfg = _REGISTRY[name]()
    cfg.validate()
    return cfg


def available_archs() -> Tuple[str, ...]:
    from repro import configs as _  # noqa: F401

    return tuple(sorted(_REGISTRY))
