"""xlstm-1.3b — sLSTM + mLSTM blocks, xLSTM[7:1] [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections (proj factor 2 for
mLSTM pre-up-projection blocks, ~4/3 gated FFN for sLSTM post-FFN blocks).
"""
from repro.configs.base import ArchConfig, XLSTMConfig, register


@register("xlstm-1.3b")
def xlstm_1p3b() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        source="arXiv:2405.04517",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm=XLSTMConfig(slstm_every=8, mlstm_proj_factor=2.0,
                          slstm_proj_factor=1.3334, conv1d_kernel=4),
        norm_type="layernorm",
        supports_long_context=True,   # recurrent, natively sub-quadratic
    )
