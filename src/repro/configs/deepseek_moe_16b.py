"""deepseek-moe-16b — 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066]."""
from repro.configs.base import ArchConfig, MoEConfig, register


@register("deepseek-moe-16b")
def deepseek_moe_16b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        source="arXiv:2401.06066",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        moe=MoEConfig(
            n_experts=64,
            experts_per_token=6,
            d_expert=1408,
            n_shared_experts=2,
            first_dense_layers=1,
            dense_d_ff=10944,      # paper's first dense layer width
        ),
        long_context_window=4096,
        mlp_type="swiglu",
        norm_type="rmsnorm",
    )
