"""Block-based (paged) KV cache bookkeeping — the vLLM idea in host code.

A request's logical KV sequence is mapped onto fixed-size *physical* blocks
drawn from a shared pool, so memory is committed one block at a time as the
sequence grows instead of one dense ``cache_len`` slab per slot.  Two layers:

  * :class:`BlockAllocator` — the physical pool: a free-list plus per-block
    reference counts (refcount > 1 means the block is shared between
    sequences, e.g. a forked or prefix-matched block).
  * :class:`KVCacheManager` — per-sequence logical->physical block tables
    with ``allocate`` / ``append_token`` / ``rewind`` / ``free`` / ``fork``
    APIs, and the padded numpy block-table matrix the jitted decode step
    consumes.  ``rewind`` is the speculative-decode rollback: it drops a
    sequence's tail back to the accepted watermark, freeing blocks that
    only held rejected draft tokens and leaving the pool (and the prefix
    cache) exactly as if only the accepted tokens had been appended.

Physical block 0 is reserved as the *null block*: idle engine lanes point
their table at it so the jitted scatter always has a legal target, and no
live sequence is ever given block 0.

All bookkeeping here is in terms of *global* block ids, and that is a
load-bearing contract for mesh-sharded serving: a sharded engine cuts
only the ``kv_heads`` axis of the device pools, never the block axis, so
every shard holds its head slice of **every** block and this module's
tables/refcounts/digests describe all shards at once (the per-shard pool
invariant, ``docs/ARCHITECTURE.md`` §7).  Data-parallel slices each own
a full private allocator — nothing here is shared between slices.

Prefix sharing (``enable_prefix_cache=True``): every *full* block is
content-hashed over its token ids chained to its prefix
(``digest = H(parent_digest, block_tokens)``), and the manager keeps one
reference of its own on each registered block.  A newly admitted sequence
(:meth:`begin_seq`) walks its feed block-by-block through the hash table and
*attaches* the longest chain of matching full blocks instead of recomputing
them; partially-filled blocks are never returned by the lookup.  Blocks whose
only remaining reference is the cache's own hold are *evictable*: they are
reclaimed LRU-first when the free list runs dry, so cached prefixes never
block admissions.  Writing into a block that is still shared (refcount > 1 —
e.g. the tail block of a fully-matched prompt whose last token must be
re-processed to produce logits) triggers **copy-on-write**: a fresh block is
allocated, a ``(src, dst)`` device-copy op is queued for the engine to apply
to the KV pools before its next step, and the sequence's table is repointed.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NULL_BLOCK = 0


class BlockAllocator:
    """Free-list allocator with reference counting over a fixed pool.

    Block ids run ``1..num_blocks-1`` (0 is the reserved null block).
    """

    def __init__(self, num_blocks: int) -> None:
        """Create a pool of ``num_blocks`` blocks (block 0 stays reserved)."""
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null block)")
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        self._refs: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        """Blocks on the free list (excludes cache-held evictable blocks)."""
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        """Blocks currently holding at least one reference."""
        return len(self._refs)

    def allocate(self) -> int:
        """Pop a free block (refcount 1); RuntimeError when the pool is dry."""
        if not self._free:
            raise RuntimeError("out of KV cache blocks")
        blk = self._free.popleft()
        self._refs[blk] = 1
        return blk

    def refcount(self, block_id: int) -> int:
        """Current reference count of ``block_id`` (0 if unallocated)."""
        return self._refs.get(block_id, 0)

    def incref(self, block_id: int) -> None:
        """Add one reference to an allocated block."""
        if block_id not in self._refs:
            raise KeyError(f"block {block_id} is not allocated")
        self._refs[block_id] += 1

    def decref(self, block_id: int) -> None:
        """Drop one reference; the block returns to the free list at zero."""
        if block_id not in self._refs:
            raise KeyError(f"block {block_id} is not allocated")
        self._refs[block_id] -= 1
        if self._refs[block_id] == 0:
            del self._refs[block_id]
            self._free.append(block_id)


@dataclasses.dataclass
class SeqBlocks:
    """One sequence's logical view: table[i] holds tokens [i*bs, (i+1)*bs).

    ``digests`` is the hash chain of this sequence's *completed* full blocks
    and ``pending`` the token ids of the current partial block — both only
    maintained when the prefix cache is on and token contents are known
    (``pending is None`` marks the sequence unhashable).  ``history`` is
    the full token-id record (attached prefix + every appended token),
    kept in lockstep with ``pending`` so :meth:`KVCacheManager.rewind` can
    rebuild the partial-block hash state after a speculative rollback
    crosses a block boundary.
    """
    table: List[int] = dataclasses.field(default_factory=list)
    n_tokens: int = 0
    digests: List[str] = dataclasses.field(default_factory=list)
    pending: Optional[List[int]] = None
    history: Optional[List[int]] = None
    # chain indexes (positions in ``digests``) whose cache registration
    # THIS sequence created (vs attached/pre-existing content) — the set
    # :meth:`KVCacheManager.rewind` must un-register when those blocks
    # turn out to hold rejected speculative tokens
    registered: set = dataclasses.field(default_factory=set)


def chain_digest(parent: str, tokens: Sequence[int]) -> str:
    """Content hash of one full KV block chained to its prefix.

    ``parent`` is the previous block's chain digest (``""`` for the first
    block of a sequence), ``tokens`` the block's token ids.  The digest
    therefore identifies the *entire token prefix* up to and including
    this block, not just the block's own contents — two sequences share a
    digest iff they share every token from position 0.  Pure function of
    the token ids (sha256 over little-endian int64 bytes), so digests are
    stable across processes and hosts: the prefix cache, the KV-block
    wire format (:mod:`repro.serving.transfer`), and the on-disk
    prefix-cache persistence format all key on the same value.
    """
    h = hashlib.sha256()
    h.update(parent.encode())
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.hexdigest()


_digest = chain_digest


class KVCacheManager:
    """Maps logical KV sequences onto the physical block pool.

    ``block_size`` tokens per block; ``max_blocks_per_seq`` bounds a single
    sequence (the engine's ``cache_len`` ceiling).  All model layers share
    one block table per sequence — a physical block id indexes every layer's
    pool at once.
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 max_blocks_per_seq: int,
                 enable_prefix_cache: bool = False) -> None:
        """Build the manager over a fresh ``num_blocks``-block pool."""
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.enable_prefix_cache = enable_prefix_cache
        self._seqs: Dict[int, SeqBlocks] = {}
        # prefix cache state: digest -> block, block -> digest, LRU of
        # blocks whose only reference is the cache's own hold
        self._cached: Dict[str, int] = {}
        self._block_digest: Dict[int, str] = {}
        # digest -> (parent digest, block tokens): the provenance needed to
        # export a cached block onto the wire (or to disk) and to recompute
        # its chain digest on the receiving side
        self._cached_meta: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._copy_ops: List[Tuple[int, int]] = []
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0
        self.cow_copies = 0
        self.evictions = 0
        # speculative-rollback accounting (rewind calls that dropped >= 1
        # token; blocks_rewound counts blocks freed because they only held
        # rejected tokens)
        self.rewinds = 0
        self.tokens_rewound = 0
        self.blocks_rewound = 0
        # bumped whenever the set of cached digests changes — lets the
        # scheduler skip re-hashing a blocked prompt when nothing moved
        self.cache_version = 0
        # can_admit -> begin_seq handoff: the admission plan for one feed,
        # so back-to-back check+admit hashes the prompt once, not twice
        self._plan_cache = None
        # tiered-KV hooks, installed by the engine when the host swap tier
        # is on: ``host_has(digest) -> bool`` says a full block's payload is
        # resident in the host pool (so admission can swap it in instead of
        # recomputing); ``on_swap_out(digest, blk, parent, tokens)`` fires
        # just before an eviction drops a registered block, while its
        # device payload is still addressable
        self.host_has = None
        self.on_swap_out = None
        self._swap_in_ops: List[Tuple[str, int]] = []
        self.swap_ins = 0
        self.swapped_in_tokens = 0
        # cancellation accounting (release_seq / release_chain)
        self.released_seqs = 0
        self.swap_ins_dropped = 0

    # ------------------------------------------------------------------
    def _protected_blocks(self) -> frozenset:
        """Device blocks a still-valid admission plan counted as prefix
        hits.  Evicting one silently converts the planned cache hit into a
        recompute, so the accounting below shields them while the plan is
        live (a stale plan — cache_version moved on — protects nothing)."""
        plan = self._plan_cache
        if plan is None or plan[1] != self.cache_version:
            return frozenset()
        return frozenset(b for b in plan[3] if b is not None)

    def free_blocks(self, protect: frozenset = frozenset(), *,
                    planned: bool = True) -> int:
        """THE free-block accounting rule: free-list blocks plus cache-only
        (LRU) blocks, excluding ``protect`` and — unless ``planned=False``
        — blocks shielded by a live admission plan.  ``num_free_blocks``,
        ``can_admit``/``_plan_admission`` and the scheduler's slot-guarantee
        loop all route through here, so "how many blocks can I still draw"
        has exactly one answer everywhere."""
        guard = frozenset(protect) | \
            (self._protected_blocks() if planned else frozenset())
        if not guard:
            return self.allocator.num_free + len(self._lru)
        return self.allocator.num_free + sum(
            1 for b in self._lru if b not in guard)

    def drop_plan_protection(self) -> None:
        """Surrender the cached admission plan (and the eviction shield on
        its prefix hits).  The scheduler calls this when every reclaimable
        block is a planned hit and the alternative is preempting live work
        — the plan's owner re-plans on its next admission attempt."""
        self._plan_cache = None

    @property
    def num_free_blocks(self) -> int:
        """Blocks available for new allocations: the free list plus cached
        blocks no live sequence references (evicted on demand), minus any
        blocks a live admission plan counted as prefix hits."""
        return self.free_blocks()

    def n_tokens(self, seq_id: int) -> int:
        """Current logical length of sequence ``seq_id`` in tokens."""
        return self._seqs[seq_id].n_tokens

    def has_seq(self, seq_id: int) -> bool:
        """True when ``seq_id`` is registered with the manager."""
        return seq_id in self._seqs

    def blocks_needed(self, n_tokens: int) -> int:
        """Physical blocks required to hold ``n_tokens`` tokens (ceil)."""
        return -(-n_tokens // self.block_size)          # ceil

    def can_allocate(self, n_tokens: int) -> bool:
        """Prefix-blind admission check against free + evictable blocks."""
        need = self.blocks_needed(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} blocks, over the "
                f"per-seq ceiling {self.max_blocks_per_seq}")
        return need <= self.num_free_blocks

    # ------------------------------------------------------------------
    # internal pool plumbing (eviction-aware)
    # ------------------------------------------------------------------
    def _evict_one(self, protect: frozenset = frozenset()) -> bool:
        """Reclaim the coldest cache-only block that is neither in
        ``protect`` nor shielded by a live admission plan.  When the swap
        hook is installed the block's payload is offered to the host tier
        first (its device bytes are still addressable here — eviction only
        ever reclaims blocks whose content landed in an earlier step).
        Returns False when every LRU block is protected."""
        guard = frozenset(protect) | self._protected_blocks()
        blk = next((b for b in self._lru if b not in guard), None)
        if blk is None:
            return False
        self._lru.pop(blk)
        digest = self._block_digest.pop(blk)
        if self.on_swap_out is not None:
            parent, tokens = self._cached_meta.get(digest, ("", ()))
            if tokens:
                self.on_swap_out(digest, blk, parent, tokens)
        del self._cached[digest]
        self._cached_meta.pop(digest, None)
        self.allocator.decref(blk)          # drop the cache's hold -> free
        self.evictions += 1
        self.cache_version += 1
        return True

    def _alloc_block(self, protect: frozenset = frozenset()) -> int:
        if self.allocator.num_free == 0 and self._lru:
            self._evict_one(protect)
        return self.allocator.allocate()

    def _attach(self, blk: int) -> None:
        """Take a sequence reference on an existing (cached) block."""
        self.allocator.incref(blk)
        self._lru.pop(blk, None)            # in use again: not evictable

    def _release(self, blk: int) -> None:
        """Drop a sequence reference; cache-held blocks become evictable."""
        self.allocator.decref(blk)
        if blk in self._block_digest and self.allocator.refcount(blk) == 1:
            self._lru[blk] = None
            self._lru.move_to_end(blk)

    def _register_full_block(self, seq: SeqBlocks) -> None:
        """The sequence just completed a full block: chain-hash it and (if
        this content is new) register the block for prefix sharing."""
        parent = seq.digests[-1] if seq.digests else ""
        tokens = seq.pending
        digest = _digest(parent, tokens)
        seq.digests.append(digest)
        seq.pending = []
        if digest in self._cached:
            return                          # identical content already cached
        blk = seq.table[(seq.n_tokens - 1) // self.block_size]
        self._cached[digest] = blk
        self._block_digest[blk] = digest
        self._cached_meta[digest] = (parent, tuple(tokens))
        self.allocator.incref(blk)          # the cache's own hold
        seq.registered.add(len(seq.digests) - 1)
        self.cache_version += 1

    def _match_prefix(self, feed: Sequence[int]
                      ) -> Tuple[List[str], List[Optional[int]]]:
        """Longest chain of *full* blocks covering a prefix of feed.

        Each source is a device block id for a device-resident hit, or
        ``None`` for a host-tier hit (the payload lives in the engine's
        host pool and must be swapped into a fresh device block — cheaper
        than recomputing it, but it does consume a pool block)."""
        digests: List[str] = []
        sources: List[Optional[int]] = []
        parent = ""
        bs = self.block_size
        for i in range(0, len(feed) - len(feed) % bs, bs):
            d = _digest(parent, feed[i:i + bs])
            blk = self._cached.get(d)
            if blk is None and (self.host_has is None
                                or not self.host_has(d)):
                break
            digests.append(d)
            sources.append(blk)
            parent = d
        return digests, sources

    # ------------------------------------------------------------------
    def lookup_prefix(self, feed: Sequence[int]) -> int:
        """Number of feed tokens covered by cached full blocks (always a
        multiple of ``block_size`` — partially-filled blocks never match)."""
        if not self.enable_prefix_cache:
            return 0
        _, sources = self._match_prefix([int(t) for t in feed])
        return len(sources) * self.block_size

    # ------------------------------------------------------------------
    # transfer / persistence hooks (see repro.serving.transfer)
    # ------------------------------------------------------------------
    def has_digest(self, digest: str) -> bool:
        """True when a full block with this chain digest is cached."""
        return digest in self._cached

    def cached_digests(self) -> frozenset:
        """Chain digests of every full block the prefix cache holds."""
        return frozenset(self._cached)

    def export_chain(self, feed: Sequence[int]
                     ) -> List[Tuple[str, str, int, List[int]]]:
        """Walk ``feed`` through the cache and export the longest chain of
        cached full blocks covering its prefix.

        Returns ``[(digest, parent_digest, physical_block, tokens), ...]``
        in chain order (parents before children).  The physical block ids
        let the engine read the actual KV payloads off the device pools;
        the (parent, tokens) pairs are everything a receiver needs to
        recompute and verify the digests.  Stops at the first un-cached
        block, exactly like prefix matching at admission.
        """
        out: List[Tuple[str, str, int, List[int]]] = []
        parent = ""
        bs = self.block_size
        feed = [int(t) for t in feed]
        for i in range(0, len(feed) - len(feed) % bs, bs):
            tokens = feed[i:i + bs]
            d = _digest(parent, tokens)
            blk = self._cached.get(d)
            if blk is None:
                break
            out.append((d, parent, blk, tokens))
            parent = d
        return out

    def export_all_cached(self) -> List[Tuple[str, str, int, List[int]]]:
        """Export every cached full block, as :meth:`export_chain` tuples.

        Registration order is preserved, which puts parents before their
        children for chains built by a single sequence; a chain whose
        parent was LRU-evicted exports as an orphan that simply never
        matches on the importing side (harmless dead weight, evicted there
        in turn).  This is the prefix-cache persistence path: serialize
        the result with :class:`repro.serving.transfer.KVShipment` and the
        wire format doubles as the on-disk format.
        """
        out: List[Tuple[str, str, int, List[int]]] = []
        for digest, blk in self._cached.items():
            parent, tokens = self._cached_meta[digest]
            out.append((digest, parent, blk, list(tokens)))
        return out

    def import_block(self, parent: str, tokens: Sequence[int], *,
                     digest: Optional[str] = None) -> Optional[int]:
        """Register one full block arriving from another engine (or disk).

        Allocates a physical block, registers it under
        ``chain_digest(parent, tokens)`` exactly as if a local sequence had
        completed it, and returns the block id so the caller can write the
        KV payload into the device pools.  The cache's own hold is the only
        reference, so the imported block goes straight onto the LRU — it is
        evictable and never crowds out live sequences, though importing can
        itself evict cold cached blocks when the free list is dry.

        Returns ``None`` when the digest is already cached (the dedup-skip:
        content-addressing makes re-imports free).  ``digest``, when given,
        is cross-checked against the recomputed chain digest — a mismatch
        means the token history was corrupted in flight and raises
        ``ValueError``.  Raises ``RuntimeError`` when live sequences hold
        the whole pool and nothing is evictable.
        """
        if not self.enable_prefix_cache:
            raise RuntimeError("import_block requires enable_prefix_cache")
        tokens = [int(t) for t in tokens]
        if len(tokens) != self.block_size:
            raise ValueError(
                f"imported block has {len(tokens)} tokens, expected a full "
                f"block of {self.block_size}")
        d = _digest(parent, tokens)
        if digest is not None and digest != d:
            raise ValueError(
                "chain digest mismatch: token history does not hash to the "
                "advertised digest")
        if d in self._cached:
            return None
        blk = self._alloc_block()
        self._cached[d] = blk
        self._block_digest[blk] = d
        self._cached_meta[d] = (parent, tuple(tokens))
        # sole ref is the cache's hold -> immediately evictable
        self._lru[blk] = None
        self._lru.move_to_end(blk)
        self.cache_version += 1
        return blk

    def _plan_admission(self, feed: Sequence[int]
                        ) -> Tuple[List[str], List[Optional[int]], int]:
        """Choose the cached prefix blocks a new sequence would attach.
        Returns (digests, sources, num_computed); sources holds device
        block ids, with ``None`` marking host-tier hits that swap in.  A
        full-feed match forces the capped last token's write into the
        shared tail block (a copy-on-write fork needing one extra block);
        when the pool cannot afford that fork — or the tail hit is
        host-resident, where a swap-in PLUS a fork costs more than just
        recomputing one block — the last matched block is dropped from the
        plan, so the tail recomputes into a fresh/evicted block instead."""
        digests, sources = self._match_prefix(feed)
        matched = len(sources) * self.block_size
        num_computed = min(matched, len(feed) - 1)
        if num_computed < matched:       # full match -> CoW on first write
            shared = frozenset(s for s in sources if s is not None)
            avail = self.free_blocks(protect=shared, planned=False)
            if sources[-1] is None or avail < 1:
                digests, sources = digests[:-1], sources[:-1]
                num_computed = len(sources) * self.block_size
        return digests, sources, num_computed

    def can_admit(self, feed: Sequence[int]) -> bool:
        """Prefix-aware admission check: can the pool cover ``feed`` given
        the full blocks a prefix match would share (plus the copy-on-write
        fork a fully-matched prompt needs)?  Host-tier hits save compute
        but still draw a device block each, so they count as allocations
        here."""
        need = self.blocks_needed(len(feed))
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {len(feed)} tokens needs {need} blocks, over "
                f"the per-seq ceiling {self.max_blocks_per_seq}")
        if not self.enable_prefix_cache or need <= self.allocator.num_free:
            # fast path also skips re-hashing a blocked prompt every step
            return need <= self.num_free_blocks
        feed = [int(t) for t in feed]
        digests, sources, num_computed = self._plan_admission(feed)
        self._plan_cache = (feed, self.cache_version,
                            digests, sources, num_computed)
        extra = 1 if num_computed < len(sources) * self.block_size else 0
        n_device = sum(1 for s in sources if s is not None)
        shared = frozenset(s for s in sources if s is not None)
        return need - n_device + extra \
            <= self.free_blocks(protect=shared, planned=False)

    def begin_seq(self, seq_id: int, feed: Sequence[int]) -> int:
        """Register a sequence, sharing the longest cached prefix of its
        feed.  Returns the number of already-computed tokens (the caller's
        cursor start) — capped at ``len(feed) - 1`` so at least one token is
        processed to produce logits.  When that cap lands mid-block the
        shared tail block is attached anyway; the first write into it
        triggers copy-on-write."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        if not self.enable_prefix_cache or not len(feed):
            self.allocate(seq_id, 0)
            return 0
        feed = [int(t) for t in feed]
        cached = self._plan_cache
        self._plan_cache = None
        if cached and cached[0] == feed and cached[1] == self.cache_version:
            digests, sources, num_computed = cached[2:]
        else:
            digests, sources, num_computed = self._plan_admission(feed)
        n_attach = self.blocks_needed(num_computed)
        sources = sources[:n_attach]
        shared = frozenset(s for s in sources if s is not None)
        bs = self.block_size
        table: List[int] = []
        for i, src in enumerate(sources):
            if src is not None:
                self._attach(src)
                table.append(src)
                continue
            # host-tier hit: draw a fresh device block (never evicting a
            # device hit of this same plan) and register it under the
            # chain digest exactly as if a local sequence had completed
            # it; the engine writes the host payload into the block
            # before the next step reads it (take_swap_ins)
            blk = self._alloc_block(protect=shared)
            self._cached[digests[i]] = blk
            self._block_digest[blk] = digests[i]
            self._cached_meta[digests[i]] = (
                digests[i - 1] if i else "",
                tuple(feed[i * bs:(i + 1) * bs]))
            self.allocator.incref(blk)      # the cache's own hold
            self._swap_in_ops.append((digests[i], blk))
            self.swap_ins += 1
            self.swapped_in_tokens += bs
            self.cache_version += 1
            table.append(blk)
        n_full = num_computed // bs
        seq = SeqBlocks(table=table, n_tokens=num_computed,
                        digests=digests[:n_full],
                        pending=feed[n_full * bs:num_computed],
                        history=feed[:num_computed])
        self._seqs[seq_id] = seq
        if num_computed:
            self.prefix_hits += 1
            self.prefix_tokens_reused += num_computed
        return num_computed

    def take_swap_ins(self) -> List[Tuple[str, int]]:
        """Drain queued host->device swap-ins as ``(digest, block)`` pairs.
        The engine must write each digest's host payload into the device
        pools before its next step (and before applying CoW copies — a
        stale swap-in target that was recycled as a CoW destination must
        end up holding the copy, not the host bytes)."""
        ops, self._swap_in_ops = self._swap_in_ops, []
        return ops

    def digest_block(self, digest: str) -> Optional[int]:
        """Device block currently registered under ``digest`` (None when
        evicted) — lets the engine drop swap-in writes whose target block
        was reclaimed before the payload landed."""
        return self._cached.get(digest)

    def seq_swap_preserved(self, seq_id: int) -> int:
        """Full blocks of ``seq_id`` whose contents survive a ``free()``:
        they are registered in the prefix cache, so with the host swap
        tier installed a preemption degrades to a swap-out (re-admission
        swaps them back in) instead of a recompute."""
        seq = self._seqs.get(seq_id)
        if seq is None or seq.pending is None:
            return 0
        return sum(1 for d in seq.digests if d in self._cached)

    def take_copy_ops(self) -> List[Tuple[int, int]]:
        """Drain queued copy-on-write ``(src, dst)`` block copies.  The
        engine must apply them to the device KV pools before its next step
        writes into the ``dst`` blocks."""
        ops, self._copy_ops = self._copy_ops, []
        return ops

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, n_tokens: int = 0) -> None:
        """Register a sequence and pre-allocate blocks for n_tokens."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.blocks_needed(n_tokens)
        if need > self.num_free_blocks:
            raise RuntimeError(
                f"seq {seq_id} needs {need} blocks, "
                f"{self.num_free_blocks} free")
        # pre-allocated contents are unknown: such sequences are unhashable
        hashable = self.enable_prefix_cache and n_tokens == 0
        seq = SeqBlocks(pending=[] if hashable else None,
                        history=[] if hashable else None)
        for _ in range(need):
            seq.table.append(self._alloc_block())
        seq.n_tokens = n_tokens
        self._seqs[seq_id] = seq

    def append_needs_block(self, seq_id: int) -> bool:
        """True when the next ``append_token`` must draw a block from the
        pool — either crossing into a new logical block, or a copy-on-write
        of a shared tail block."""
        seq = self._seqs[seq_id]
        bi = seq.n_tokens // self.block_size
        if bi >= len(seq.table):
            return True
        return self.allocator.refcount(seq.table[bi]) > 1

    def append_token(self, seq_id: int,
                     token: Optional[int] = None) -> Optional[int]:
        """Grow the sequence by one token; returns the newly allocated
        physical block id when the token crosses a block boundary (or a
        copy-on-write replaced the shared tail block), else None.  Raises
        RuntimeError when the pool is exhausted (the scheduler turns that
        into a preemption).  ``token`` is the id being appended — needed for
        prefix-cache hashing; hashing is disabled for the sequence when
        omitted."""
        seq = self._seqs[seq_id]
        bi = seq.n_tokens // self.block_size
        new_block: Optional[int] = None
        if bi >= len(seq.table):
            if len(seq.table) >= self.max_blocks_per_seq:
                raise ValueError(
                    f"seq {seq_id} exceeds max_blocks_per_seq "
                    f"({self.max_blocks_per_seq})")
            new_block = self._alloc_block()
            seq.table.append(new_block)
        else:
            blk = seq.table[bi]
            if self.allocator.refcount(blk) > 1:
                # copy-on-write: the tail block is shared (other sequences
                # and/or the cache hold it) — never write into it
                new_block = self._alloc_block()
                self._copy_ops.append((blk, new_block))
                self._release(blk)
                seq.table[bi] = new_block
                self.cow_copies += 1
        seq.n_tokens += 1
        if seq.pending is not None:
            if token is None:
                seq.pending = None          # content unknown: stop hashing
                seq.history = None
            else:
                seq.pending.append(int(token))
                seq.history.append(int(token))
                if len(seq.pending) == self.block_size:
                    self._register_full_block(seq)
        return new_block

    def rewind(self, seq_id: int, n_tokens: int) -> None:
        """Roll a sequence's tail back to ``n_tokens`` — the speculative
        decode rollback: tokens past the new end (rejected drafts) are
        logically dropped.

        Blocks that only held rejected tokens are released (a shared or
        cache-held block is only decref'd, never reclaimed or mutated in
        place — copy-on-write still protects any other holder).  Cache
        registrations THIS sequence created for now-rejected full blocks
        are un-registered, so the prefix cache ends up exactly as if only
        the accepted tokens had ever been appended; registrations that
        pre-existed (attached prefixes, content another sequence cached
        first) are left alone.  The digest chain is truncated and the
        partial-block hash state rebuilt from the retained token history,
        so a later re-completion of the tail block re-hashes cleanly.
        Stale KV left in the retained tail block's upper slots is
        unreachable: every attention read masks positions past the
        query's own, and the next appends overwrite (or CoW-fork) those
        slots before they are ever covered."""
        seq = self._seqs[seq_id]
        if not 0 <= n_tokens <= seq.n_tokens:
            raise ValueError(
                f"cannot rewind seq {seq_id} to {n_tokens} tokens "
                f"(has {seq.n_tokens})")
        if n_tokens == seq.n_tokens:
            return
        self.rewinds += 1
        self.tokens_rewound += seq.n_tokens - n_tokens
        if seq.pending is not None:
            n_full = n_tokens // self.block_size
            for idx in [i for i in seq.registered if i >= n_full]:
                seq.registered.discard(idx)
                digest = seq.digests[idx]
                blk = self._cached.get(digest)
                if blk is not None and \
                        self._block_digest.get(blk) == digest:
                    del self._cached[digest]
                    del self._block_digest[blk]
                    self._cached_meta.pop(digest, None)
                    self._lru.pop(blk, None)
                    self.allocator.decref(blk)  # drop the cache's hold
                    self.cache_version += 1
            del seq.digests[n_full:]
            seq.pending = list(
                seq.history[n_full * self.block_size:n_tokens])
            del seq.history[n_tokens:]
        keep = self.blocks_needed(n_tokens)
        for blk in seq.table[keep:]:
            # blocks_rewound counts only blocks actually reclaimed: a
            # block still shared (fork / prefix attach) is merely
            # decref'd and stays allocated for its other holders
            if self.allocator.refcount(blk) == 1:
                self.blocks_rewound += 1
            self._release(blk)
        del seq.table[keep:]
        seq.n_tokens = n_tokens

    def free(self, seq_id: int) -> None:
        """Drop a finished sequence's references.  Blocks the prefix cache
        registered stay resident (the cache's own hold keeps them) and
        become evictable; unshared blocks return to the free list."""
        seq = self._seqs.pop(seq_id)
        for blk in seq.table:
            self._release(blk)

    def _unregister(self, digest: str, blk: int) -> None:
        """Drop one prefix-cache registration and the cache's block hold."""
        del self._cached[digest]
        del self._block_digest[blk]
        self._cached_meta.pop(digest, None)
        self._lru.pop(blk, None)
        self.allocator.decref(blk)          # drop the cache's hold
        self.cache_version += 1

    def _drop_stale_swap_ins(self) -> None:
        """Drop queued host->device swap-ins whose target block no longer
        holds the registration they were queued against — the cancellation
        path un-registers blocks mid-flight, and writing a host payload
        into a block that has since been freed (or recycled) would corrupt
        whoever owns it now.  Ops whose registration is intact (e.g. a
        swap-in block another live sequence attached to) are kept."""
        keep: List[Tuple[str, int]] = []
        for d, blk in self._swap_in_ops:
            if self._cached.get(d) != blk:
                self.swap_ins_dropped += 1
            else:
                keep.append((d, blk))
        self._swap_in_ops = keep

    def release_seq(self, seq_id: int) -> List[str]:
        """Cancellation teardown for a live sequence: free its blocks AND
        un-register the chain blocks only it (plus the cache) was holding,
        so a cancelled request leaves no KV residue behind.

        Contrast with :meth:`free` (normal completion), which deliberately
        leaves the chain registered for future prefix hits.  A cancelled
        request's chain is dead weight *unless another holder is alive*:
        a block whose refcount exceeds 2 (this seq + the cache's hold)
        is shared with another live sequence, so its registration — and
        any pending swap-in payload write targeting it — survives; the
        last cancelling holder takes it down.  Queued swap-ins whose
        registration this call removed are dropped (``swap_ins_dropped``),
        and any cached admission plan is surrendered (its free-block
        shield must not outlive a cancellation that changed the pool).

        Returns the chain digests no longer device-registered afterwards —
        the engine purges exactly these from the host swap tier.
        """
        seq = self._seqs[seq_id]
        owned = set(seq.table)
        for digest in seq.digests:
            blk = self._cached.get(digest)
            if blk is None or self._block_digest.get(blk) != digest:
                continue
            if blk in owned and self.allocator.refcount(blk) == 2:
                self._unregister(digest, blk)
        purge = [d for d in seq.digests if d not in self._cached]
        self.free(seq_id)
        self._drop_stale_swap_ins()
        self._plan_cache = None
        self.released_seqs += 1
        return purge

    def release_chain(self, feed: Sequence[int]) -> List[str]:
        """Cancellation teardown for a request with no live sequence (still
        waiting, or preempted with its KV swapped out): walk the feed's
        chain and reclaim cache-only device blocks, collecting the digests
        whose payloads now live only in the host tier so the engine can
        purge them.  Blocks still referenced by a live sequence are left
        registered (that sequence's own release handles them later).

        The walk does NOT stop at the first missing block: an earlier
        cancellation may have unregistered a shared chain *head* while
        deeper blocks of this chain still sit in the host tier (eviction
        order is LRU, not chain order) — those deep entries are
        unreachable garbage (admission matches from the head), so the
        walk covers every full block of the feed.
        """
        if not self.enable_prefix_cache:
            return []
        feed = [int(t) for t in feed]
        purge: List[str] = []
        parent = ""
        bs = self.block_size
        for i in range(0, len(feed) - len(feed) % bs, bs):
            d = _digest(parent, feed[i:i + bs])
            blk = self._cached.get(d)
            if blk is not None:
                if self._block_digest.get(blk) == d \
                        and self.allocator.refcount(blk) == 1:
                    self._unregister(d, blk)
                    purge.append(d)
            elif self.host_has is not None and self.host_has(d):
                purge.append(d)
            parent = d
        self._drop_stale_swap_ins()
        self._plan_cache = None
        return purge

    def fork(self, src_seq_id: int, dst_seq_id: int) -> None:
        """Share the source's blocks with a new sequence (refcounted).

        Forks are only allowed at block-aligned lengths; a later write into
        any still-shared block copy-on-writes it (see ``append_token``).
        """
        src = self._seqs[src_seq_id]
        if src.n_tokens % self.block_size != 0:
            raise ValueError("fork requires a block-aligned source length")
        if dst_seq_id in self._seqs:
            raise KeyError(f"seq {dst_seq_id} already allocated")
        dst = SeqBlocks(table=list(src.table), n_tokens=src.n_tokens,
                        digests=list(src.digests),
                        pending=None if src.pending is None
                        else list(src.pending),
                        history=None if src.history is None
                        else list(src.history))
        for blk in dst.table:
            self._attach(blk)
        self._seqs[dst_seq_id] = dst

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int) -> List[int]:
        """Copy of the sequence's logical->physical block table."""
        return list(self._seqs[seq_id].table)

    def padded_table(self, seq_id: int) -> np.ndarray:
        """(max_blocks_per_seq,) int32 row for the jitted step; unallocated
        logical blocks point at the null block."""
        row = np.full((self.max_blocks_per_seq,), NULL_BLOCK, np.int32)
        table = self._seqs[seq_id].table
        row[:len(table)] = table
        return row

    def utilization(self) -> float:
        """Fraction of non-null pool blocks currently allocated (cached
        prefix blocks count: they hold live KV)."""
        total = self.allocator.num_blocks - 1
        return (total - self.allocator.num_free) / max(total, 1)
