"""Block-based (paged) KV cache bookkeeping — the vLLM idea in host code.

A request's logical KV sequence is mapped onto fixed-size *physical* blocks
drawn from a shared pool, so memory is committed one block at a time as the
sequence grows instead of one dense ``cache_len`` slab per slot.  Two layers:

  * :class:`BlockAllocator` — the physical pool: a free-list plus per-block
    reference counts (refcount > 1 means the block is shared between
    sequences, e.g. a forked prefix).
  * :class:`KVCacheManager` — per-sequence logical->physical block tables
    with ``allocate`` / ``append_token`` / ``free`` / ``fork`` APIs, and the
    padded numpy block-table matrix the jitted decode step consumes.

Physical block 0 is reserved as the *null block*: idle engine lanes point
their table at it so the jitted scatter always has a legal target, and no
live sequence is ever given block 0.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

NULL_BLOCK = 0


class BlockAllocator:
    """Free-list allocator with reference counting over a fixed pool.

    Block ids run ``1..num_blocks-1`` (0 is the reserved null block).
    """

    def __init__(self, num_blocks: int) -> None:
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the null block)")
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        self._refs: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._refs)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("out of KV cache blocks")
        blk = self._free.popleft()
        self._refs[blk] = 1
        return blk

    def refcount(self, block_id: int) -> int:
        return self._refs.get(block_id, 0)

    def incref(self, block_id: int) -> None:
        if block_id not in self._refs:
            raise KeyError(f"block {block_id} is not allocated")
        self._refs[block_id] += 1

    def decref(self, block_id: int) -> None:
        """Drop one reference; the block returns to the free list at zero."""
        if block_id not in self._refs:
            raise KeyError(f"block {block_id} is not allocated")
        self._refs[block_id] -= 1
        if self._refs[block_id] == 0:
            del self._refs[block_id]
            self._free.append(block_id)


@dataclasses.dataclass
class SeqBlocks:
    """One sequence's logical view: table[i] holds tokens [i*bs, (i+1)*bs)."""
    table: List[int] = dataclasses.field(default_factory=list)
    n_tokens: int = 0


class KVCacheManager:
    """Maps logical KV sequences onto the physical block pool.

    ``block_size`` tokens per block; ``max_blocks_per_seq`` bounds a single
    sequence (the engine's ``cache_len`` ceiling).  All model layers share
    one block table per sequence — a physical block id indexes every layer's
    pool at once.
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 max_blocks_per_seq: int) -> None:
        self.allocator = BlockAllocator(num_blocks)
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self._seqs: Dict[int, SeqBlocks] = {}

    # ------------------------------------------------------------------
    @property
    def num_free_blocks(self) -> int:
        return self.allocator.num_free

    def n_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def has_seq(self, seq_id: int) -> bool:
        return seq_id in self._seqs

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)          # ceil

    def can_allocate(self, n_tokens: int) -> bool:
        need = self.blocks_needed(n_tokens)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} blocks, over the "
                f"per-seq ceiling {self.max_blocks_per_seq}")
        return need <= self.allocator.num_free

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, n_tokens: int = 0) -> None:
        """Register a sequence and pre-allocate blocks for n_tokens."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.blocks_needed(n_tokens)
        if need > self.allocator.num_free:
            raise RuntimeError(
                f"seq {seq_id} needs {need} blocks, "
                f"{self.allocator.num_free} free")
        seq = SeqBlocks()
        for _ in range(need):
            seq.table.append(self.allocator.allocate())
        seq.n_tokens = n_tokens
        self._seqs[seq_id] = seq

    def append_token(self, seq_id: int) -> Optional[int]:
        """Grow the sequence by one token; returns the newly allocated
        physical block id when the token crosses a block boundary, else
        None.  Raises RuntimeError when the pool is exhausted (the
        scheduler turns that into a preemption)."""
        seq = self._seqs[seq_id]
        if seq.n_tokens % self.block_size == 0:
            if len(seq.table) >= self.max_blocks_per_seq:
                raise ValueError(
                    f"seq {seq_id} exceeds max_blocks_per_seq "
                    f"({self.max_blocks_per_seq})")
            new = self.allocator.allocate()
            seq.table.append(new)
            seq.n_tokens += 1
            return new
        seq.n_tokens += 1
        return None

    def free(self, seq_id: int) -> None:
        seq = self._seqs.pop(seq_id)
        for blk in seq.table:
            self.allocator.decref(blk)

    def fork(self, src_seq_id: int, dst_seq_id: int) -> None:
        """Share the source's blocks with a new sequence (refcounted).

        The fork is read-only sharing for the already-written prefix; the
        first ``append_token`` past a shared *partial* tail block would need
        copy-on-write, so forks are only allowed at block-aligned lengths.
        """
        src = self._seqs[src_seq_id]
        if src.n_tokens % self.block_size != 0:
            raise ValueError("fork requires a block-aligned source length")
        if dst_seq_id in self._seqs:
            raise KeyError(f"seq {dst_seq_id} already allocated")
        dst = SeqBlocks(table=list(src.table), n_tokens=src.n_tokens)
        for blk in dst.table:
            self.allocator.incref(blk)
        self._seqs[dst_seq_id] = dst

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].table)

    def padded_table(self, seq_id: int) -> np.ndarray:
        """(max_blocks_per_seq,) int32 row for the jitted step; unallocated
        logical blocks point at the null block."""
        row = np.full((self.max_blocks_per_seq,), NULL_BLOCK, np.int32)
        table = self._seqs[seq_id].table
        row[:len(table)] = table
        return row

    def utilization(self) -> float:
        """Fraction of non-null pool blocks currently allocated."""
        total = self.allocator.num_blocks - 1
        return (total - self.allocator.num_free) / max(total, 1)
