from repro.serving.batch import (BatchEngine, BatchStats,  # noqa: F401
                                 RaggedBatch, TileMap, build_tile_map)
from repro.serving.blocks import (BlockAllocator, KVCacheManager,  # noqa: F401
                                  NULL_BLOCK)
from repro.serving.engine import (DecodeEngine, PagedDecodeEngine,  # noqa: F401
                                  SlotDecodeEngine)
from repro.serving.scheduler import (Request, RequestState,  # noqa: F401
                                     Scheduler, SchedulerConfig,
                                     StepDecision)
from repro.serving.spec import NgramProposer, Proposer  # noqa: F401
