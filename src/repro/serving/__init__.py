"""LM serving stack: paged-KV engines, scheduling, speculation, transfer.

See docs/ARCHITECTURE.md for the design reference tying the pieces
together; each submodule's docstring states its own contracts.
"""
from repro.serving.batch import (BatchEngine, BatchStats,  # noqa: F401
                                 RaggedBatch, TileMap, build_tile_map)
from repro.serving.blocks import (BlockAllocator, KVCacheManager,  # noqa: F401
                                  NULL_BLOCK, chain_digest)
from repro.serving.engine import (DecodeEngine, PagedDecodeEngine,  # noqa: F401
                                  ShardedDecodeEngine, SlotDecodeEngine)
from repro.serving.frontend import (AsyncEngine, OpenRequest,  # noqa: F401
                                    Ticket, run_open_loop)
from repro.serving.scheduler import (Request, RequestState,  # noqa: F401
                                     Scheduler, SchedulerConfig,
                                     StepDecision)
from repro.serving.spec import NgramProposer, Proposer  # noqa: F401
from repro.serving.transfer import (DisaggregatedEngine,  # noqa: F401
                                    KVBlockRecord, KVShipment,
                                    TransferIntegrityError,
                                    edge_dc_topology, payload_checksum)
