from repro.serving.engine import BatchEngine, DecodeEngine, Request  # noqa: F401
