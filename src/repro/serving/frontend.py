"""Async streaming frontend and open-loop serving harness.

Two entry points on top of the batch engines (engine.py):

  * :class:`AsyncEngine` — the online surface.  A dedicated **step
    thread** owns every engine touch (``submit`` / ``cancel`` /
    ``step`` / ``take_finished``); callers talk to it through a
    lock-protected mailbox, so the thread-unsafe engine internals are
    serialized by construction.  Per-token delivery rides the engine's
    ``on_token`` streaming hook (fired inside ``step()`` on the step
    thread) into per-request sinks; :meth:`AsyncEngine.stream` adapts a
    sink to an ``async`` generator, and a consumer that disconnects
    (``asyncio.CancelledError``) cancels its request mid-flight — which
    frees the sequence's KV blocks, prefix-cache residue, queued
    swap-ins, and host-tier payloads (``PagedDecodeEngine.cancel``).

  * :func:`run_open_loop` — the paper's evaluation shape: requests
    arrive on a Poisson-style schedule (arrival times are the caller's,
    pre-seeded), the engine steps whenever work exists, and a shared
    :class:`~repro.core.simclock.SimClock` stamps every latency mark.
    Real step wall time accrues to the virtual clock via
    ``clock.measure``; idle gaps between arrivals are simulated with
    ``clock.advance`` — so goodput-vs-offered-load curves are
    deterministic given the arrival schedule, yet use measured compute.

Cancellation invariants (the test walls pin these):

  * a cancel is only ever applied **between** engine steps — the step
    thread drains the cancel mailbox before calling ``step()``;
  * cancelling an unknown/finished id is a no-op returning False;
  * after cancelling everything and draining, the block pool and the
    host swap tier are empty (no leaked refcounts, no orphaned
    payloads, no stale queued swap-ins).
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.simclock import SimClock
from repro.serving.scheduler import Request


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Ticket:
    """Handle for one in-flight :class:`AsyncEngine` request.

    ``done`` is set when the request finishes, is cancelled, or is shed
    by SLO admission; ``result`` then holds the engine's
    :class:`~repro.serving.scheduler.Request` record.  ``sink`` (if set)
    receives ``(token, finished)`` pairs from the step thread as they
    are emitted; after a terminal event with no final token (cancel /
    shed) it receives ``(None, True)``.
    """

    prompt: np.ndarray
    max_new_tokens: int
    priority: int = 0
    sink: Optional[Callable[[Optional[int], bool], None]] = None
    request_id: Optional[int] = None
    result: Optional[Request] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    _terminal_sent: bool = False

    def _push(self, tok: int, finished: bool) -> None:
        if finished:
            self._terminal_sent = True
        if self.sink is not None:
            self.sink(tok, finished)

    def _resolve(self, result: Request) -> None:
        self.result = result
        if not self._terminal_sent:
            self._terminal_sent = True
            if self.sink is not None:
                self.sink(None, True)
        self.done.set()


class AsyncEngine:
    """Asyncio-friendly streaming frontend over one decode engine.

    All engine access happens on the internal step thread; ``submit``
    and ``cancel`` only enqueue intents into a mailbox and wake it.  Use
    as a context manager::

        with AsyncEngine(engine) as fe:
            ticket = fe.submit(prompt, max_new_tokens=32)
            req = fe.result(ticket)          # blocking
            # or, inside an event loop:
            async for tok in fe.stream(prompt, 32):
                ...

    A consumer cancelling :meth:`stream` (client disconnect) aborts the
    request on the engine, freeing its KV immediately rather than
    decoding tokens nobody will read.
    """

    def __init__(self, engine: Any) -> None:
        """Wrap ``engine`` (paged / sharded / slot — anything with the
        ``submit / cancel / step / has_work / take_finished / on_token``
        surface).  The engine must not be touched by other threads while
        the frontend is running."""
        self.engine = engine
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._pending: deque = deque()       # tickets awaiting submit
        self._cancels: deque = deque()       # tickets awaiting cancel
        self._by_rid: Dict[int, Ticket] = {}
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self.steps = 0

    # ------------------------------------------------------------------
    def start(self) -> "AsyncEngine":
        """Install the streaming hook and launch the step thread."""
        if self._running:
            return self
        self.engine.on_token = self._dispatch
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name="async-engine-step", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the step thread (drains nothing: pending work stays on
        the engine) and detach the streaming hook."""
        with self._wake:
            if not self._running:
                return
            self._running = False
            self._wake.notify()
        assert self._thread is not None
        self._thread.join()
        self._thread = None
        self.engine.on_token = None

    def __enter__(self) -> "AsyncEngine":
        """Context-manager entry: :meth:`start`."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Context-manager exit: :meth:`stop`."""
        self.stop()

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0,
               sink: Optional[Callable[[Optional[int], bool], None]] = None,
               ) -> Ticket:
        """Enqueue a request; returns its :class:`Ticket` immediately.

        ``sink(token, finished)`` — if given — is called from the step
        thread per emitted token (keep it cheap and thread-safe; for
        asyncio consumers use :meth:`stream` instead, which wraps a sink
        in ``loop.call_soon_threadsafe``).
        """
        ticket = Ticket(np.asarray(prompt, np.int32), max_new_tokens,
                        priority=priority, sink=sink)
        with self._wake:
            if not self._running:
                raise RuntimeError("AsyncEngine is not running "
                                   "(use `with AsyncEngine(engine):`)")
            self._pending.append(ticket)
            self._wake.notify()
        return ticket

    def cancel(self, ticket: Ticket) -> None:
        """Request cancellation of ``ticket``.  Applied by the step
        thread between engine steps; no-op if already finished."""
        with self._wake:
            if ticket.done.is_set():
                return
            if ticket.request_id is None and ticket in self._pending:
                # never reached the engine: resolve it right here
                self._pending.remove(ticket)
                req = Request(-1, ticket.prompt, ticket.max_new_tokens,
                              priority=ticket.priority)
                req.done = True
                req.cancelled = True
                ticket._resolve(req)
                return
            self._cancels.append(ticket)
            self._wake.notify()

    def result(self, ticket: Ticket,
               timeout: Optional[float] = None) -> Request:
        """Block until ``ticket`` resolves; returns the engine's request
        record (check ``.cancelled`` / ``.shed``)."""
        if not ticket.done.wait(timeout):
            raise TimeoutError("request did not resolve in time")
        assert ticket.result is not None
        return ticket.result

    async def stream(self, prompt: np.ndarray, max_new_tokens: int,
                     priority: int = 0):
        """Async generator yielding tokens as the engine emits them.

        Cancelling the consuming task (client disconnect) aborts the
        request on the engine — the mid-flight KV teardown path.
        """
        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()
        ticket = self.submit(
            prompt, max_new_tokens, priority=priority,
            sink=lambda tok, fin: loop.call_soon_threadsafe(
                q.put_nowait, (tok, fin)))
        try:
            while True:
                tok, fin = await q.get()
                if tok is not None:
                    yield tok
                if fin:
                    break
        finally:
            # normal exhaustion: done already set, cancel() is a no-op
            self.cancel(ticket)

    # ------------------------------------------------------------------
    def _dispatch(self, rid: int, tok: int, finished: bool) -> None:
        # step-thread context (fired inside engine.step())
        ticket = self._by_rid.get(rid)
        if ticket is not None:
            ticket._push(tok, finished)

    def _loop(self) -> None:
        while True:
            with self._wake:
                while (self._running and not self._pending
                       and not self._cancels
                       and not self.engine.has_work()):
                    self._wake.wait()
                if not self._running:
                    return
                pending = list(self._pending)
                self._pending.clear()
                cancels = list(self._cancels)
                self._cancels.clear()
            # engine work happens OUTSIDE the lock: submit/cancel only
            # touch the mailbox, so they never block on a running step
            for t in pending:
                t.request_id = self.engine.submit(
                    t.prompt, t.max_new_tokens, priority=t.priority)
                self._by_rid[t.request_id] = t
            for t in cancels:
                if t.request_id is not None and not t.done.is_set():
                    self.engine.cancel(t.request_id)
            if self.engine.has_work():
                self.engine.step()
                self.steps += 1
            for r in self.engine.take_finished():
                ticket = self._by_rid.pop(r.request_id, None)
                if ticket is not None:
                    ticket._resolve(r)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class OpenRequest:
    """One request of an open-loop arrival schedule.

    ``t_arrival`` is in virtual seconds; ``cancel_after`` (if set)
    aborts the request that many virtual seconds after arrival — the
    harness's client-disconnect model.
    """

    prompt: np.ndarray
    max_new_tokens: int
    t_arrival: float
    priority: int = 0
    cancel_after: Optional[float] = None


def run_open_loop(engine: Any, requests: Sequence[OpenRequest], *,
                  clock: Optional[SimClock] = None,
                  ttft_target: float = 0.0, tpot_target: float = 0.0,
                  max_steps: int = 100_000) -> Dict[str, Any]:
    """Drive ``engine`` through an open-loop arrival schedule on a
    virtual clock; returns per-request records and goodput aggregates.

    Requests are submitted when the clock reaches their ``t_arrival``
    (idle gaps are simulated with ``clock.advance``; compute accrues
    real measured step time via ``clock.measure``), cancels fire at
    ``t_arrival + cancel_after``, and a request **meets SLO** when it
    completes (not cancelled/shed) with TTFT and TPOT within the given
    targets (0 = don't check).  ``goodput_ratio`` is met-SLO completions
    over offered requests, excluding intentional harness cancels.
    """
    clock = clock or SimClock()
    engine.set_clock(clock)
    if ttft_target > 0 or tpot_target > 0:
        scheds = ([e.scheduler for e in engine.engines]
                  if hasattr(engine, "engines")
                  else [engine.scheduler])
        for s in scheds:
            s.cfg.ttft_target = ttft_target
            s.cfg.tpot_target = tpot_target

    arrivals = sorted(requests, key=lambda r: r.t_arrival)
    by_rid: Dict[int, OpenRequest] = {}
    cancels: List[tuple] = []       # (t_cancel, rid) — unordered heap-lite
    next_arrival = 0
    finished: List[Request] = []
    steps = 0
    while True:
        now = clock.now
        while (next_arrival < len(arrivals)
               and arrivals[next_arrival].t_arrival <= now):
            o = arrivals[next_arrival]
            rid = engine.submit(o.prompt, o.max_new_tokens,
                                priority=o.priority)
            by_rid[rid] = o
            if o.cancel_after is not None:
                cancels.append((o.t_arrival + o.cancel_after, rid))
            next_arrival += 1
        due = [(t, rid) for (t, rid) in cancels if t <= now]
        if due:
            cancels = [(t, rid) for (t, rid) in cancels if t > now]
            for _, rid in sorted(due):
                engine.cancel(rid)
        if engine.has_work():
            with clock.measure("step"):
                engine.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"open loop exceeded {max_steps} steps")
        else:
            horizon = [arrivals[next_arrival].t_arrival] \
                if next_arrival < len(arrivals) else []
            horizon += [t for (t, _) in cancels]
            if not horizon:
                finished.extend(engine.take_finished())
                break
            clock.advance(max(min(horizon) - clock.now, 0.0),
                          "idle (awaiting arrivals)")
        finished.extend(engine.take_finished())

    records = []
    met = completed = n_cancelled = n_shed = 0
    for r in finished:
        status = ("cancelled" if r.cancelled
                  else "shed" if r.shed else "ok")
        ttft = (r.t_first_token - r.t_submit
                if r.t_first_token > 0.0 else None)
        tpot = ((r.t_done - r.t_first_token)
                / max(len(r.generated) - 1, 1)
                if status == "ok" and r.t_first_token > 0.0 else None)
        ok = (status == "ok"
              and (ttft_target <= 0
                   or (ttft is not None and ttft <= ttft_target))
              and (tpot_target <= 0
                   or (tpot is not None and tpot <= tpot_target)))
        met += ok
        completed += status == "ok"
        n_cancelled += status == "cancelled"
        n_shed += status == "shed"
        records.append({"request_id": r.request_id, "status": status,
                        "priority": r.priority, "ttft": ttft,
                        "tpot": tpot, "met_slo": bool(ok),
                        "tokens": len(r.generated)})

    offered = len(requests)
    denom = max(offered - n_cancelled, 1)
    ttfts = sorted(x["ttft"] for x in records if x["ttft"] is not None)

    def _pct(p: float) -> Optional[float]:
        if not ttfts:
            return None
        return ttfts[min(int(p * len(ttfts)), len(ttfts) - 1)]

    span = (arrivals[-1].t_arrival - arrivals[0].t_arrival
            if len(arrivals) > 1 else 0.0)
    return {
        "offered": offered,
        "completed": completed,
        "met_slo": met,
        "cancelled": n_cancelled,
        "shed": n_shed,
        "goodput_ratio": met / denom,
        "offered_rps": offered / span if span > 0 else float("inf"),
        "goodput_rps": met / clock.now if clock.now > 0 else 0.0,
        "makespan": clock.now,
        "ttft_p50": _pct(0.50),
        "ttft_p95": _pct(0.95),
        "steps": steps,
        "records": records,
    }
