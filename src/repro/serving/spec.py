"""Draft-token proposers for speculative multi-token decode.

The serving engine's speculative path amortizes the memory-bound decode
step: instead of one model step per generated token per lane, a *proposer*
guesses ``k`` candidate tokens for each decode lane, the lane is scheduled
as one ``1 + k``-token ragged segment (the same multi-token segments the
chunked-prefill path already runs), and the single model step's
per-position greedy argmax verifies the guesses — the longest matching
draft prefix is accepted plus one *bonus* token (the argmax at the first
mismatching / final row).  Verification is exact: greedy outputs are
token-identical to the non-speculative engine whatever the proposer
emits, so proposers only trade compute for acceptance rate, never
correctness.

:class:`NgramProposer` is the model-free default (vLLM's n-gram /
prompt-lookup idea): the continuation is guessed from the request's *own*
token history, which is free and surprisingly effective on the
structured, self-repeating outputs long generations settle into.  A small
draft *model* can slot in behind the same :class:`Proposer` interface
later — the scheduler/engine contract only needs ``propose``.
"""
from __future__ import annotations

from typing import List, Sequence


class Proposer:
    """Interface: guess up to ``k`` continuation tokens for one request.

    ``tokens`` is the request's full known history (prompt + generated so
    far, the engine's ``feed``); the return value is a list of at most
    ``k`` draft token ids extending it.  Proposals may be arbitrarily
    wrong — the engine verifies every draft against the model's own
    greedy argmax before accepting — so implementations should optimize
    acceptance rate, not worst-case safety.  An empty list means "no
    guess": the lane falls back to plain one-token decode this step.
    """

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        """Return up to ``k`` draft token ids extending ``tokens``."""
        raise NotImplementedError


class NgramProposer(Proposer):
    """Model-free n-gram / prompt-lookup proposer.

    Finds the most recent earlier occurrence of the history's final
    n-gram (longest ``n`` first, ``max_ngram`` down to ``min_ngram``) and
    proposes the tokens that followed it.  Repetitive or templated
    continuations — looping generations, copied spans, structured
    records — match long n-grams and get near-full acceptance; histories
    with no self-match propose nothing and cost nothing.

    ``lookback`` caps how far back the match scan reaches, so the
    per-step host cost stays O(lookback * max_ngram) instead of growing
    quadratically with the generation length.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 lookback: int = 1024) -> None:
        """Set the n-gram match range and the history scan window."""
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"({min_ngram}, {max_ngram})")
        if lookback < 2:
            raise ValueError(f"lookback must be >= 2, got {lookback}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.lookback = lookback

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        """Prompt-lookup: propose what followed the last matching n-gram."""
        toks = [int(t) for t in tokens[-self.lookback:]]
        n_hist = len(toks)
        if k <= 0 or n_hist < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, n_hist - 1),
                       self.min_ngram - 1, -1):
            tail = toks[n_hist - n:]
            # most recent earlier occurrence wins (recent context is the
            # best predictor of what follows the pattern this time) —
            # except that a match hugging the tail has fewer than k
            # followers, so the most recent match with a FULL k-token
            # continuation is preferred: on a period-p loop that turns
            # "propose p tokens" into "propose k tokens", the whole win.
            # A match always has >= 1 follower (it ends before the tail).
            fallback: List[int] = []
            for start in range(n_hist - n - 1, -1, -1):
                if toks[start:start + n] == tail:
                    if n_hist - start - n >= k:
                        return toks[start + n:start + n + k]
                    if not fallback:
                        fallback = toks[start + n:start + n + k]
            if fallback:
                return fallback
        return []
