"""Serving batch containers.

Three kinds live here:

  * :class:`RaggedBatch` — the flat-token serving batch: one 1-D stream of
    *all* tokens an engine step schedules (mixed multi-token prefill chunks
    and single-token decodes, each request a contiguous segment) plus
    per-token metadata (owning lane, absolute position, physical KV slot).
    Replaces the rectangular ``(n_lanes, chunk_width)`` layout in which one
    lane prefilling a 256-token chunk forced every decoding lane to pad 1
    real token out to 256.  Bucketing is pow2 on *total tokens*.
  * :class:`TileMap` — the segment-tiled view of a RaggedBatch consumed by
    the tiled paged-attention path: the flat stream is cut into fixed
    pow2-sized q-row windows, each window is split at the segment
    boundaries crossing it, and every resulting (window, segment)
    intersection becomes one *tile* that sweeps exactly one lane's KV
    blocks — KV is read once per tile instead of once per token.
  * :class:`BatchEngine` — stateless batched inference (BraggNN /
    CookieNetAE at the edge): dynamic micro-batching with a latency budget,
    padded to fixed compiled batch sizes.

Every array a RaggedBatch/TileMap carries is host-built per-step metadata;
under mesh-sharded serving the engine commits them fully *replicated* (the
replicated-metadata contract, ``docs/ARCHITECTURE.md`` §7): the flat token
stream is never cut across devices — only weight- and KV-touching tensors
shard — so nothing in this module is mesh-aware.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def padded_pow2(n: int, cap: int = 0) -> int:
    """Smallest power of two >= n (optionally capped).  Every serving
    engine pads variable work to a few fixed compiled shapes with this:
    BatchEngine its micro-batches, the rectangular paged step its per-step
    chunk width, RaggedBatch its flat total-token count — bounding
    recompiles to O(log cap) instead of one per observed size."""
    size = 1
    while size < n:
        size *= 2
    return min(size, cap) if cap else size


@dataclasses.dataclass
class RaggedBatch:
    """One engine step's scheduled tokens as a flat 1-D stream.

    ``tokens[q_starts[rid] : q_starts[rid] + seg_lens[rid]]`` is request
    ``rid``'s contiguous segment (a prefill chunk, a single decode token,
    or — speculative decode — one feed token followed by ``seg_drafts``
    proposer drafts, verified by the same step's per-row argmax); segments
    are packed back to back in schedule order and the tail is padded to a
    pow2 bucket (capped at the scheduler's token budget).  Per token:

      * ``token_lane``   — owning engine lane (selects the block-table row
        the attention read gathers through);
      * ``token_pos``    — absolute position in its own sequence (RoPE
        anchor + causal bound; in-chunk causality falls out of it);
      * ``slot_mapping`` — physical KV pool slot the token's K/V is
        written to, ``block_id * block_size + offset``.

    Padding tokens carry lane 0 / position 0 / slot 0 (the reserved null
    block): legal targets whose outputs the engine never reads.
    ``last_row[lane]`` is the flat index of that lane's final real token —
    the only logits row that can emit a new token.
    """
    tokens: np.ndarray                 # (T_pad,) int32
    token_lane: np.ndarray             # (T_pad,) int32
    token_pos: np.ndarray              # (T_pad,) int32
    slot_mapping: np.ndarray           # (T_pad,) int32
    last_row: np.ndarray               # (n_lanes,) int32
    q_starts: Dict[int, int]           # request_id -> flat segment offset
    seg_lens: Dict[int, int]           # request_id -> segment length
    # request_id -> trailing speculative draft rows in the segment (a
    # spec decode lane's segment is 1 feed token + seg_drafts[rid]
    # drafts; prefill segments carry 0).  The engine verifies rows
    # [q_starts + seg_lens - seg_drafts - 1, q_starts + seg_lens) of the
    # step's argmax against the drafts.
    seg_drafts: Dict[int, int]
    total_tokens: int                  # real scheduled tokens
    padded_tokens: int                 # bucketed flat length T_pad
    n_draft_tokens: int = 0            # sum of seg_drafts values

    @property
    def padding_efficiency(self) -> float:
        """Real tokens / padded flat slots — 1.0 means zero waste."""
        return self.total_tokens / max(self.padded_tokens, 1)

    @classmethod
    def build(cls, decision, kv, n_lanes: int, block_size: int, *,
              cap: int = 0) -> "RaggedBatch":
        """Flatten a :class:`~repro.serving.scheduler.StepDecision` into
        the per-token arrays the jitted ragged step consumes.  ``kv`` is
        the :class:`KVCacheManager` *after* ``schedule()`` guaranteed every
        scheduled token a slot (block tables are final, incl. any
        copy-on-write repointing).  ``cap`` bounds the pow2 bucket (the
        scheduler's token budget); totals above it are left exact."""
        total = sum(decision.num_scheduled[r.request_id]
                    for r in decision.scheduled)
        if cap and cap < max(total, 1):
            padded = max(total, 1)          # over-budget total: stay exact
        else:
            padded = padded_pow2(max(total, 1), cap)
        tokens = np.zeros((padded,), np.int32)
        token_lane = np.zeros((padded,), np.int32)
        token_pos = np.zeros((padded,), np.int32)
        slot_mapping = np.zeros((padded,), np.int32)
        last_row = np.zeros((n_lanes,), np.int32)
        q_starts: Dict[int, int] = {}
        seg_lens: Dict[int, int] = {}
        seg_drafts: Dict[int, int] = {}
        n_drafts = 0
        off = 0
        for r in decision.scheduled:
            n = decision.num_scheduled[r.request_id]
            table = np.asarray(kv.block_table(r.request_id), np.int64)
            ps = np.arange(r.cursor, r.cursor + n)
            # a speculative decode lane's segment is its feed token plus
            # its draft tokens, at consecutive positions — verification
            # is just this segment riding the ordinary multi-token path
            tokens[off:off + n] = decision.segment_tokens(r)
            token_lane[off:off + n] = r.lane
            token_pos[off:off + n] = ps
            slot_mapping[off:off + n] = (table[ps // block_size] * block_size
                                         + ps % block_size)
            last_row[r.lane] = off + n - 1
            q_starts[r.request_id] = off
            seg_lens[r.request_id] = n
            seg_drafts[r.request_id] = len(
                decision.drafts.get(r.request_id, ()))
            n_drafts += seg_drafts[r.request_id]
            off += n
        return cls(tokens=tokens, token_lane=token_lane,
                   token_pos=token_pos, slot_mapping=slot_mapping,
                   last_row=last_row, q_starts=q_starts, seg_lens=seg_lens,
                   seg_drafts=seg_drafts, total_tokens=total,
                   padded_tokens=padded, n_draft_tokens=n_drafts)

    def tiles(self, n_lanes: int, tile: int) -> "TileMap":
        """The segment-tiled view of this batch (see :class:`TileMap`).
        Segments are recovered from ``q_starts``/``seg_lens`` in stream
        order; lane and first position come from the per-token arrays."""
        segs = sorted((off, self.seg_lens[rid]) for rid, off
                      in self.q_starts.items())
        seg_lanes = [int(self.token_lane[off]) for off, _ in segs]
        seg_pos0 = [int(self.token_pos[off]) for off, _ in segs]
        return build_tile_map([s[0] for s in segs], [s[1] for s in segs],
                              seg_lanes, seg_pos0, self.padded_tokens,
                              n_lanes, tile)


# rows of TileMap.meta — one (5, n_tiles) int32 array so the jitted step
# carries a single scalar-prefetch operand per tile map.  The kernel layer
# owns the contract; re-exported here for the serving-side builders/tests.
from repro.kernels.ref import (TILE_HI, TILE_LANE, TILE_LO,  # noqa: E402,F401
                               TILE_POS0, TILE_WINDOW)


@dataclasses.dataclass
class TileMap:
    """Segment-tiled decomposition of one flat token stream.

    The padded stream is covered by ``ceil(padded_tokens / tile)`` fixed
    q-row *windows* of ``tile`` rows each; a window crossing one or more
    segment boundaries is split at them, and every (window, segment)
    intersection is a *tile*.  A tile therefore always lies inside a single
    window (its q rows are one contiguous slab of that window) AND inside a
    single segment (all its rows share one lane / block table, so the
    kernel DMAs that lane's KV blocks once for the whole tile).

    ``meta`` is (5, capacity) int32, row ``r`` of tile ``t``:

      * ``meta[TILE_WINDOW, t]`` — window index (q-row block the tile loads);
      * ``meta[TILE_LO, t]``/``meta[TILE_HI, t]`` — the tile's flat-row span
        ``[lo, hi)``; rows of the window outside it are masked in-kernel;
      * ``meta[TILE_POS0, t]`` — absolute sequence position of row ``lo``
        (row ``q`` sits at ``pos0 + q - lo``: the causal bound);
      * ``meta[TILE_LANE, t]`` — owning lane (block-table row to sweep).

    ``capacity`` is the *static* upper bound ``n_windows + n_lanes`` (each
    of the <= n_lanes segments adds at most one window split), so the
    jitted step retraces per pow2 token bucket only, never per tile count.
    Speculative decode needs no extra metadata here: a ``1 + k`` draft
    segment is just a multi-token segment, split at window boundaries and
    swept against its lane's KV exactly like a prefill chunk.
    Tiles past ``n_tiles`` are inert: ``lo == hi`` skips all compute.
    ``row_tile[q]`` maps every real flat row to its owning tile (padding
    rows map to tile 0 — their output is garbage the engine never reads).
    ``cu_seqlens`` (n_segs + 1,) are the segment boundaries in the flat
    stream: segment s is rows ``[cu_seqlens[s], cu_seqlens[s+1])``.
    """
    meta: np.ndarray                   # (5, capacity) int32
    row_tile: np.ndarray               # (padded_tokens,) int32
    cu_seqlens: np.ndarray             # (n_segs + 1,) int32
    n_tiles: int                       # real tiles (<= capacity)
    tile: int                          # q-window row count (pow2)


def build_tile_map(seg_offsets, seg_lens, seg_lanes, seg_pos0,
                   padded_tokens: int, n_lanes: int, tile: int) -> TileMap:
    """Cut back-to-back segments into (window, segment) tiles.

    ``seg_offsets``/``seg_lens``/``seg_lanes``/``seg_pos0`` describe the
    segments in stream order (offsets must be contiguous from 0 — the
    scheduler packs them back to back); ``padded_tokens`` is the bucketed
    flat length the windows must cover.
    """
    if tile < 1 or tile & (tile - 1):
        raise ValueError(f"tile must be a positive power of two, got {tile}")
    n_windows = -(-max(padded_tokens, 1) // tile)
    capacity = n_windows + n_lanes
    meta = np.zeros((5, capacity), np.int32)
    row_tile = np.zeros((padded_tokens,), np.int32)
    cu = [0]
    t = 0
    for off, n, lane, pos0 in zip(seg_offsets, seg_lens, seg_lanes,
                                  seg_pos0):
        if off != cu[-1]:
            raise ValueError(
                f"segments must be contiguous: expected offset {cu[-1]}, "
                f"got {off}")
        cu.append(off + n)
        row = off
        while row < off + n:
            if t >= capacity:
                raise ValueError(
                    f"tile capacity {capacity} exceeded: more than "
                    f"{n_lanes} segments for {n_windows} windows?")
            w = row // tile
            hi = min(off + n, (w + 1) * tile)
            meta[:, t] = (w, row, hi, pos0 + (row - off), lane)
            row_tile[row:hi] = t
            row, t = hi, t + 1
    return TileMap(meta=meta, row_tile=row_tile,
                   cu_seqlens=np.asarray(cu, np.int32), n_tiles=t, tile=tile)


@dataclasses.dataclass
class BatchStats:
    """Rolling counters for :class:`BatchEngine` (requests, batches, latency)."""

    n_requests: int = 0
    n_batches: int = 0
    total_items: int = 0
    total_latency: float = 0.0

    def summary(self) -> Dict[str, float]:
        """Counters plus mean per-batch latency, as a plain dict."""
        return {
            "requests": self.n_requests,
            "batches": self.n_batches,
            "items": self.total_items,
            "mean_latency_s": self.total_latency / max(self.n_batches, 1),
        }


class BatchEngine:
    """Fixed-shape compiled batched inference with padding.

    ``apply_fn(params, x) -> y``; compiled once per allowed batch size
    (powers of two up to ``max_batch``), requests padded up to the nearest.
    """

    def __init__(self, apply_fn: Callable, params: PyTree, *,
                 max_batch: int = 1024) -> None:
        """Jit ``apply_fn`` once; batches are padded to pow2 sizes."""
        self.params = params
        self.max_batch = max_batch
        self._jitted = jax.jit(apply_fn)
        self.stats = BatchStats()

    def _padded_size(self, n: int) -> int:
        return padded_pow2(n, self.max_batch)

    def infer(self, x: np.ndarray) -> np.ndarray:
        """Process a request of any size by padded fixed-shape batches."""
        self.stats.n_requests += 1
        outs = []
        i = 0
        n = x.shape[0]
        while i < n:
            take = min(self.max_batch, n - i)
            size = self._padded_size(take)
            chunk = x[i:i + take]
            if take < size:
                pad = np.zeros((size - take,) + x.shape[1:], x.dtype)
                chunk = np.concatenate([chunk, pad])
            t0 = time.perf_counter()
            y = np.asarray(self._jitted(self.params, jnp.asarray(chunk)))
            self.stats.total_latency += time.perf_counter() - t0
            self.stats.n_batches += 1
            self.stats.total_items += take
            outs.append(y[:take])
            i += take
        return np.concatenate(outs) if len(outs) > 1 else outs[0]
