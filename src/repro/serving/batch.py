"""Stateless batched inference (BraggNN / CookieNetAE at the edge):
dynamic micro-batching with a latency budget, padded to fixed compiled
batch sizes (edge accelerators compile fixed shapes)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def padded_pow2(n: int, cap: int = 0) -> int:
    """Smallest power of two >= n (optionally capped).  Both engines pad
    variable work to a few fixed compiled shapes with this: BatchEngine its
    micro-batches, PagedDecodeEngine its per-step chunk width — bounding
    recompiles to O(log cap) instead of one per observed size."""
    size = 1
    while size < n:
        size *= 2
    return min(size, cap) if cap else size


@dataclasses.dataclass
class BatchStats:
    n_requests: int = 0
    n_batches: int = 0
    total_items: int = 0
    total_latency: float = 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.n_requests,
            "batches": self.n_batches,
            "items": self.total_items,
            "mean_latency_s": self.total_latency / max(self.n_batches, 1),
        }


class BatchEngine:
    """Fixed-shape compiled batched inference with padding.

    ``apply_fn(params, x) -> y``; compiled once per allowed batch size
    (powers of two up to ``max_batch``), requests padded up to the nearest.
    """

    def __init__(self, apply_fn: Callable, params: PyTree, *,
                 max_batch: int = 1024) -> None:
        self.params = params
        self.max_batch = max_batch
        self._jitted = jax.jit(apply_fn)
        self.stats = BatchStats()

    def _padded_size(self, n: int) -> int:
        return padded_pow2(n, self.max_batch)

    def infer(self, x: np.ndarray) -> np.ndarray:
        """Process a request of any size by padded fixed-shape batches."""
        self.stats.n_requests += 1
        outs = []
        i = 0
        n = x.shape[0]
        while i < n:
            take = min(self.max_batch, n - i)
            size = self._padded_size(take)
            chunk = x[i:i + take]
            if take < size:
                pad = np.zeros((size - take,) + x.shape[1:], x.dtype)
                chunk = np.concatenate([chunk, pad])
            t0 = time.perf_counter()
            y = np.asarray(self._jitted(self.params, jnp.asarray(chunk)))
            self.stats.total_latency += time.perf_counter() - t0
            self.stats.n_batches += 1
            self.stats.total_items += take
            outs.append(y[:take])
            i += take
        return np.concatenate(outs) if len(outs) > 1 else outs[0]
