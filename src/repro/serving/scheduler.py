"""Continuous-batching scheduler over the paged KV pool.

Every engine step is one **token-budgeted batch** that freely mixes
multi-token prefill chunks and single-token decodes — there is no
prefill/decode phase split.  Each step the scheduler assigns every request
a ``num_scheduled_tokens`` count under one shared budget:

  * **decode** lanes (next step emits a new token) are served first at one
    token each — cheap, so a flood of long prompts can never starve them;
    with a speculative :class:`~repro.serving.spec.Proposer` wired
    (``draft_k > 0``) a decode lane additionally schedules up to ``k``
    draft tokens as one ``1 + k``-token segment (the engine verifies them
    against the model's own argmax in the same step); drafted tokens count
    against the step's token budget like any other scheduled token, but a
    rejected draft never advances the request — one budget token is
    reserved per still-unserved decode lane, per running prefill lane,
    and per pending admission with a free lane, so drafts can never
    starve a sibling decode, stall a mid-prompt request, or gate
    admissions indefinitely;
  * **prefill** lanes (still consuming their prompt / replaying after
    preemption) take chunks of up to ``chunk_tokens`` from the remaining
    budget — a long prompt is consumed in a few chunked steps instead of
    one step per token;
  * **admission**: waiting requests are admitted into free lanes while the
    budget holds and the KV manager can cover their feed; with the prefix
    cache on, admission shares the longest chain of cached full blocks
    (``KVCacheManager.begin_seq``) so identical preambles are never
    re-prefilled;
  * **preemption by recompute**: when the pool runs out of blocks mid-step,
    the latest-admitted request is evicted — its blocks are freed and it
    re-enters the waiting queue with its generated tokens intact, to be
    replayed (prefill-as-recompute) once memory frees up.  If the victim
    would be the request currently being guaranteed and it already secured
    part of its chunk, the chunk is truncated instead (mid-chunk
    preemption): partial progress is kept and the step proceeds.  Greedy
    decode is deterministic, so replays reproduce the identical
    continuation.  With the engine's host swap tier installed
    (``KVCacheManager.on_swap_out``) preemption degrades to **swap-out**:
    the victim's registered full blocks stay recoverable (device prefix
    cache first, spilling to the host pool under pressure) and its
    re-admission swaps them back in instead of recomputing them.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.serving.blocks import KVCacheManager


class RequestState(enum.Enum):
    """Lifecycle of a request: waiting -> running (-> waiting again on
    preemption) -> finished."""

    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass(eq=False)          # identity semantics for in/remove
class Request:
    """One serving request plus its engine-internal progress state.

    ``feed`` is the token stream still to be pushed through the model
    (prompt + generated-so-far after a preemption replay); ``cursor`` the
    next feed index, i.e. how many of its tokens already sit in KV.
    """

    request_id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # priority class: higher admits first and is preempted last; ties
    # keep FIFO order, so the default 0 reproduces plain FIFO serving
    priority: int = 0
    # terminal disposition beyond plain completion: ``cancelled`` marks a
    # mid-flight abort (engine.cancel / a disconnected stream), ``shed``
    # an SLO admission drop — both are surfaced through the engine's
    # finished list with ``done=True`` and no further tokens
    cancelled: bool = False
    shed: bool = False
    # --- engine-internal state ---
    state: RequestState = RequestState.WAITING
    feed: List[int] = dataclasses.field(default_factory=list)
    cursor: int = 0                  # next feed index == tokens already in KV
    lane: Optional[int] = None
    n_preemptions: int = 0
    # --- latency accounting (stamped from the engine's clock: wall time,
    # or the shared SimClock in disaggregated / open-loop runs) ---
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    def begin_run(self, lane: int) -> None:
        """(Re)admission: the feed is prompt + generated-so-far; after a
        preemption the generated suffix is recomputed deterministically.
        The cursor may then be advanced past a cached shared prefix."""
        self.feed = [int(t) for t in self.prompt] + list(self.generated)
        self.cursor = 0
        self.lane = lane
        self.state = RequestState.RUNNING

    @property
    def is_decode(self) -> bool:
        """True when the next step emits a new token (vs prompt prefill)."""
        return self.cursor >= len(self.feed) - 1

    @property
    def remaining_feed(self) -> int:
        """Feed tokens not yet pushed through the model."""
        return len(self.feed) - self.cursor


@dataclasses.dataclass
class SchedulerConfig:
    """Scheduler knobs: lane count, token budget, chunking, speculation.

    See the field comments for each knob's semantics; the module
    docstring describes how they interact in one step.
    """

    n_lanes: int
    token_budget: int = 0    # 0 = n_lanes * chunk_tokens
    chunk_tokens: int = 1    # per-request tokens per step cap; 0 = unlimited
    # ragged flat-token mode: after the normal pass, extend prefill chunks
    # until the step's total token count reaches its pow2 bucket boundary
    # (capped at the budget) — the flat slots the bucket would otherwise
    # waste on padding carry real prefill work instead.  The per-segment
    # view of the resulting stream (cu_seqlens, per-segment lane/position,
    # and the segment-tiled TileMap the tiled attention grid consumes) is
    # derived from the decision by serving/batch.py — one segment per
    # scheduled request, so a step never has more segments than lanes.
    fill_to_bucket: bool = False
    # speculative decode: when a proposer is set and draft_k > 0, each
    # decode lane is offered up to draft_k draft tokens per step (see the
    # module docstring for the budget interaction)
    draft_k: int = 0
    proposer: Optional[object] = None      # repro.serving.spec.Proposer
    # SLO-aware admission (0 = off).  ``tpot_target`` (seconds per decode
    # token): while the observed decode TPOT (EWMA fed by
    # :meth:`Scheduler.observe_step`) sits above target, prefill chunks
    # shrink by powers of two (staying inside the engine's compiled chunk
    # buckets) and bucket-filling is suppressed, trading new-request
    # prefill bandwidth for in-flight decode latency.  ``ttft_target``
    # (seconds): a waiting request whose first-token deadline has already
    # passed is shed at admission time instead of burning prefill compute
    # on a request that can no longer meet its SLO (``slo_shed=False``
    # keeps the chunk-shrink behaviour but never drops requests).
    ttft_target: float = 0.0
    tpot_target: float = 0.0
    slo_shed: bool = True


@dataclasses.dataclass
class StepDecision:
    """One step's scheduling outcome: who runs, with how many tokens.

    The engine turns this into a :class:`~repro.serving.batch.RaggedBatch`
    (or a rectangular batch) — one segment per scheduled request.
    """

    scheduled: List[Request]
    # request_id -> tokens scheduled this step (>= 1 for every scheduled
    # request; decode lanes get 1 + their draft count)
    num_scheduled: Dict[int, int] = dataclasses.field(default_factory=dict)
    # request_id -> this step's speculative draft tokens (decode lanes
    # only; absent = no drafts).  A lane's scheduled segment is its feed
    # slice followed by these drafts — num_scheduled counts both.
    drafts: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    n_prefill: int = 0
    n_decode: int = 0
    n_prefill_tokens: int = 0
    n_decode_tokens: int = 0
    n_draft_tokens: int = 0          # drafted tokens scheduled this step
    n_admitted: int = 0
    n_preempted: int = 0
    # preemptions that degraded to swap-outs: the victim's registered full
    # blocks stay recoverable (device prefix cache, spilling to the host
    # tier under pressure), so its resume swaps KV back in instead of
    # recomputing it.  Counted within n_preempted, not in addition to it.
    n_swapped_out: int = 0
    prefix_cached_tokens: int = 0    # feed tokens skipped via prefix sharing

    def segment_tokens(self, req: Request) -> List[int]:
        """The token ids of ``req``'s scheduled segment, in stream order:
        its feed slice, extended by its draft tokens when it is a
        speculative decode lane."""
        n = self.num_scheduled[req.request_id]
        toks = [int(t) for t in req.feed[req.cursor:req.cursor + n]]
        if len(toks) < n:
            toks += self.drafts.get(req.request_id, [])[:n - len(toks)]
        return toks


class Scheduler:
    """Token-budgeted continuous-batching scheduler (see module docstring).

    Owns the waiting queue and the lane assignments; consults the
    :class:`~repro.serving.blocks.KVCacheManager` for admission planning
    and preemption decisions but never touches device state itself.
    """

    def __init__(self, cfg: SchedulerConfig, kv: KVCacheManager) -> None:
        """Bind the scheduler to its config and the KV block manager."""
        self.cfg = cfg
        self.kv = kv
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []          # admission (priority) order
        self.lanes: List[Optional[Request]] = [None] * cfg.n_lanes
        self.total_preemptions = 0
        self.total_swap_outs = 0
        self.total_admitted = 0
        self.total_cancelled = 0
        self.total_shed = 0
        # last admission refusal: (request, feed_len, free_blocks, version)
        # — while none of those change, re-asking (and re-hashing a long
        # prompt against the prefix cache) every step is pointless
        self._blocked_state = None
        # SLO state: the engine installs its clock here (SimClock-aware
        # engines stamp sim time; default is wall time) and feeds measured
        # step durations into the decode-TPOT EWMA via observe_step
        self.now_fn: Callable[[], float] = time.perf_counter
        self.tpot_ewma = 0.0
        self._shed: List[Request] = []

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        """Queue a new request for admission (FIFO)."""
        self.waiting.append(req)

    def has_work(self) -> bool:
        """True while any request is waiting or running."""
        return bool(self.waiting or self.running)

    def observe_step(self, seconds: float, decode_tokens: int) -> None:
        """Feed one engine step's measured duration back into the decode
        TPOT estimate (EWMA, alpha 0.3).  ``decode_tokens`` is the number
        of tokens the step emitted; steps that emitted none (pure prefill)
        carry no TPOT signal and are skipped."""
        if decode_tokens <= 0:
            return
        sample = seconds / decode_tokens
        self.tpot_ewma = (sample if self.tpot_ewma == 0.0
                          else 0.7 * self.tpot_ewma + 0.3 * sample)

    def _overloaded(self) -> bool:
        """True while the observed decode TPOT sits above its target."""
        return (self.cfg.tpot_target > 0
                and self.tpot_ewma > self.cfg.tpot_target)

    def take_shed(self) -> List[Request]:
        """Hand off requests SLO admission shed since the last call (the
        engine moves them into its finished list)."""
        out, self._shed = self._shed, []
        return out

    def _chunk(self) -> int:
        chunk = self.cfg.chunk_tokens or 1_000_000_000
        if self._overloaded():
            # halve the prefill chunk per doubling of TPOT overshoot — a
            # pow2 shrink keeps the engine inside its compiled chunk-width
            # buckets, and the floor of 1 preserves prefill liveness
            over = self.tpot_ewma / self.cfg.tpot_target
            while over > 1.0 and chunk > 1:
                chunk //= 2
                over /= 2.0
        return max(chunk, 1)

    def _budget(self) -> int:
        return self.cfg.token_budget or \
            self.cfg.n_lanes * max(1, self.cfg.chunk_tokens)

    # ------------------------------------------------------------------
    def _next_waiting(self) -> int:
        """Index of the next admission candidate: highest priority class
        first, FIFO (and preempted-resume-first) within a class.  When
        every waiting priority is equal this is index 0 — exactly the
        pre-priority admission order."""
        it = iter(self.waiting)
        first = next(it).priority
        if all(r.priority == first for r in it):
            return 0
        return max(range(len(self.waiting)),
                   key=lambda i: (self.waiting[i].priority, -i))

    def _shed_req(self, idx: int) -> None:
        """SLO shed: drop a waiting request whose TTFT deadline already
        passed — admitting it would spend prefill compute on a request
        that can no longer meet its SLO, slowing everyone else."""
        req = self.waiting[idx]
        del self.waiting[idx]
        if self._blocked_state is not None and self._blocked_state[0] is req:
            self._blocked_state = None
        req.state = RequestState.FINISHED
        req.done = True
        req.shed = True
        req.t_done = self.now_fn()
        self._shed.append(req)
        self.total_shed += 1

    def _admit(self, budget_left: int, decision: StepDecision,
               scheduled: List[Request]) -> int:
        while self.waiting and budget_left > 0 and None in self.lanes:
            idx = self._next_waiting()
            req = self.waiting[idx]
            if (self.cfg.ttft_target > 0 and self.cfg.slo_shed
                    and req.t_first_token == 0.0
                    and req.n_preemptions == 0
                    and self.now_fn() - req.t_submit
                    > self.cfg.ttft_target):
                # only never-admitted requests shed: a preempted victim
                # was already accepted (and may hold emitted tokens) —
                # dropping it would break the completion promise
                self._shed_req(idx)
                continue
            state = (req, len(req.prompt) + len(req.generated),
                     self.kv.num_free_blocks,
                     getattr(self.kv, "cache_version", 0))
            if state == self._blocked_state:
                break
            feed = [int(t) for t in req.prompt] + list(req.generated)
            if not self.kv.can_admit(feed):
                self._blocked_state = state
                break
            self._blocked_state = None
            del self.waiting[idx]
            lane = self.lanes.index(None)
            req.begin_run(lane)
            self.lanes[lane] = req
            self.running.append(req)
            # share the longest cached prefix; cursor skips past it
            req.cursor = self.kv.begin_seq(req.request_id, req.feed)
            decision.prefix_cached_tokens += req.cursor
            scheduled.append(req)
            n = min(req.remaining_feed, self._chunk(), budget_left)
            decision.num_scheduled[req.request_id] = n
            budget_left -= n
            decision.n_admitted += 1
            self.total_admitted += 1
        return budget_left

    def _preempt(self, victim: Request, decision: StepDecision,
                 scheduled: List[Request]) -> None:
        # with the host swap tier installed, a victim whose full blocks
        # are registered is swapped out rather than recomputed: free()
        # keeps those blocks recoverable through the prefix cache, the
        # eviction hook spills them host-side under pressure, and the
        # victim's re-admission swaps them back in
        if (self.kv.on_swap_out is not None
                and self.kv.seq_swap_preserved(victim.request_id) > 0):
            decision.n_swapped_out += 1
            self.total_swap_outs += 1
        self.kv.free(victim.request_id)
        self.lanes[victim.lane] = None
        victim.lane = None
        victim.state = RequestState.WAITING
        victim.n_preemptions += 1
        self.running.remove(victim)
        if victim in scheduled:
            scheduled.remove(victim)
        decision.num_scheduled.pop(victim.request_id, None)
        decision.drafts.pop(victim.request_id, None)
        self.waiting.appendleft(victim)        # resume as soon as possible
        decision.n_preempted += 1
        self.total_preemptions += 1

    def schedule(self) -> StepDecision:
        """Assign this step's per-request token counts under one budget,
        admit, and guarantee a KV slot for every scheduled token."""
        decision = StepDecision(scheduled=[])
        budget_left = self._budget()
        chunk = self._chunk()
        scheduled: List[Request] = []

        # decodes first (1 token each, plus speculative drafts): never
        # starved by prefill chunks.  Draft budgeting is fair: one budget
        # token is reserved for every decode lane still unserved behind
        # this one (a greedy 1+k segment can never push a sibling decode
        # out of the step, which would otherwise starve it forever — the
        # starved lane stays a decode next step too), for every running
        # prefill lane (drafts never reduce a mid-prompt request below
        # the one-token-per-step progress floor it had before speculation
        # existed), and for one admission when a request is waiting on a
        # free lane (a pure-decode fleet regenerates its decode state
        # every step, so without the reserve full-budget draft segments
        # would gate admissions on a lane finishing).
        decodes = [r for r in self.running if r.is_decode]
        reserve = (len(self.running) - len(decodes)
                   + (1 if self.waiting and None in self.lanes else 0))
        for i, r in enumerate(decodes):
            if budget_left <= 0:
                break
            drafts: List[int] = []
            if self.cfg.proposer is not None and self.cfg.draft_k > 0:
                # cap drafts by the fair budget share, the per-seq KV
                # ceiling (a draft past it could never be appended), and
                # the request's own remaining output (accepting more than
                # remaining - 1 drafts is wasted work: the bonus token
                # already covers the last slot)
                room = (self.kv.max_blocks_per_seq * self.kv.block_size
                        - (r.cursor + 1))
                want = min(self.cfg.draft_k,
                           budget_left - 1 - (len(decodes) - i - 1)
                           - reserve,
                           room, r.max_new_tokens - len(r.generated) - 1)
                if want > 0:
                    drafts = [int(t) for t in
                              self.cfg.proposer.propose(r.feed, want)][:want]
            scheduled.append(r)
            decision.num_scheduled[r.request_id] = 1 + len(drafts)
            if drafts:
                decision.drafts[r.request_id] = drafts
            budget_left -= 1 + len(drafts)
        # prefill chunks from the remaining budget
        for r in self.running:
            if budget_left <= 0:
                break
            if not r.is_decode:
                n = min(r.remaining_feed, chunk, budget_left)
                scheduled.append(r)
                decision.num_scheduled[r.request_id] = n
                budget_left -= n

        budget_left = self._admit(budget_left, decision, scheduled)

        # ragged bucket fill: the flat batch is padded to a pow2 total, so
        # extend prefill chunks (beyond chunk_tokens — the per-lane width
        # cap is meaningless without a rectangle) until the total lands on
        # the bucket boundary: padding slots become real prefill work.
        # Greedy decode is causal per request, so scheduling more prompt
        # tokens per step never changes any output.
        # under TPOT overload the bucket fill is suppressed along with the
        # chunk shrink: both convert spare step capacity into prefill
        # work, which is exactly what is crowding out decode latency
        if self.cfg.fill_to_bucket and decision.num_scheduled \
                and not self._overloaded():
            from repro.serving.batch import padded_pow2
            total = sum(decision.num_scheduled.values())
            spare = min(self._budget(), padded_pow2(total)) - total
            for r in scheduled:
                if spare <= 0:
                    break
                n = decision.num_scheduled[r.request_id]
                extra = min(spare, r.remaining_feed - n)
                if extra > 0:
                    decision.num_scheduled[r.request_id] = n + extra
                    spare -= extra

        # guarantee a KV slot for every scheduled token, in priority order;
        # evict from the back (latest admitted) when the pool runs dry —
        # truncating the current chunk instead when the victim would be the
        # request itself and it already made progress
        for req in [r for r in self.running if r in scheduled]:
            if req not in scheduled:           # evicted by an earlier lane
                continue
            n = decision.num_scheduled[req.request_id]
            toks = decision.segment_tokens(req)
            k = 0
            while k < n:
                self_blocked = False
                # num_free_blocks routes through KVCacheManager.free_blocks,
                # so an LRU block that a live admission plan counted as a
                # prefix hit is NOT treated as free here — evicting it would
                # silently turn the planned hit into a recompute
                while (self.kv.append_needs_block(req.request_id)
                       and self.kv.num_free_blocks == 0):
                    if self.kv.free_blocks(planned=False) > 0:
                        # every reclaimable block is shielding a planned
                        # admission hit: surrender the plan (its owner
                        # re-plans, worst case recomputing the prefix)
                        # before preempting live work
                        self.kv.drop_plan_protection()
                        continue
                    victim = self.running[-1]
                    if any(r.priority != victim.priority
                           for r in self.running):
                        # priority classes: evict the lowest class first,
                        # latest-admitted within a class (min over the
                        # reversed list keeps the default-priority victim
                        # exactly running[-1])
                        victim = min(reversed(self.running),
                                     key=lambda r: r.priority)
                    if victim is req:
                        self_blocked = True
                        break
                    self._preempt(victim, decision, scheduled)
                if self_blocked:
                    if k > 0:                  # mid-chunk: keep progress
                        break
                    if len(self.running) == 1:
                        raise RuntimeError(
                            "KV pool too small for a single sequence: "
                            f"request {req.request_id} needs a block and no "
                            "victim remains")
                    self._preempt(req, decision, scheduled)
                    break
                self.kv.append_token(req.request_id, toks[k])
                k += 1
            if req in scheduled and k < n:
                # mid-chunk truncation: a prefill chunk keeps its first k
                # tokens; a speculative decode keeps its mandatory feed
                # token plus the first k - 1 drafts
                decision.num_scheduled[req.request_id] = k
                drafts = decision.drafts.pop(req.request_id, None)
                if drafts is not None and k > 1:
                    decision.drafts[req.request_id] = drafts[:k - 1]

        decision.scheduled = scheduled
        for r in scheduled:
            n = decision.num_scheduled[r.request_id]
            if r.is_decode:
                decision.n_decode += 1
                decision.n_decode_tokens += n
                decision.n_draft_tokens += len(
                    decision.drafts.get(r.request_id, ()))
            else:
                decision.n_prefill += 1
                decision.n_prefill_tokens += n
        return decision

    # ------------------------------------------------------------------
    def finish(self, req: Request) -> None:
        """Retire a completed request: free its KV blocks and its lane."""
        req.state = RequestState.FINISHED
        req.done = True
        self.kv.free(req.request_id)
        self.lanes[req.lane] = None
        req.lane = None
        self.running.remove(req)

    def abort(self, req: Request) -> None:
        """Cancellation: detach ``req`` from the scheduler — its lane and
        the running list, or the waiting queue — WITHOUT touching its KV.
        The engine owns the KV teardown
        (:meth:`~repro.serving.blocks.KVCacheManager.release_seq` /
        ``release_chain``), which must run after this so the freed lane
        can never be re-filled while the sequence still holds blocks.
        Only legal between steps, like every scheduler mutation."""
        if req.state is RequestState.RUNNING:
            self.lanes[req.lane] = None
            req.lane = None
            self.running.remove(req)
        else:
            try:
                self.waiting.remove(req)
            except ValueError:
                pass
        if self._blocked_state is not None and self._blocked_state[0] is req:
            self._blocked_state = None
        req.state = RequestState.FINISHED
        req.done = True
        req.cancelled = True
        self.total_cancelled += 1
