"""Continuous-batching scheduler over the paged KV pool.

Each engine step decodes one token per *scheduled* lane.  The scheduler
decides, every step, which requests those are:

  * running requests are split by phase — **decode** lanes (next step emits
    a new token) are served first, **prefill** lanes (still consuming their
    prompt / replaying after preemption) fill the remaining token budget;
  * **admission**: waiting requests are admitted into free lanes while the
    token budget holds and the KV manager can cover their whole feed —
    a flood of long prompts therefore cannot starve running decodes;
  * **preemption by recompute**: when the pool runs out of blocks mid-step,
    the latest-admitted request is evicted — its blocks are freed and it
    re-enters the waiting queue with its generated tokens intact, to be
    replayed (prefill-as-recompute) once memory frees up.  Greedy decode is
    deterministic, so the replay reproduces the identical continuation.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serving.blocks import KVCacheManager


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass(eq=False)          # identity semantics for in/remove
class Request:
    request_id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- engine-internal state ---
    state: RequestState = RequestState.WAITING
    feed: List[int] = dataclasses.field(default_factory=list)
    cursor: int = 0                  # next feed index == tokens already in KV
    lane: Optional[int] = None
    n_preemptions: int = 0

    def begin_run(self, lane: int) -> None:
        """(Re)admission: the feed is prompt + generated-so-far; after a
        preemption the generated suffix is recomputed deterministically."""
        self.feed = [int(t) for t in self.prompt] + list(self.generated)
        self.cursor = 0
        self.lane = lane
        self.state = RequestState.RUNNING

    @property
    def is_decode(self) -> bool:
        """True when the next step emits a new token (vs prompt prefill)."""
        return self.cursor >= len(self.feed) - 1


@dataclasses.dataclass
class SchedulerConfig:
    n_lanes: int
    token_budget: int = 0            # 0 = unlimited (bounded by n_lanes)


@dataclasses.dataclass
class StepDecision:
    scheduled: List[Request]
    n_prefill: int = 0
    n_decode: int = 0
    n_admitted: int = 0
    n_preempted: int = 0


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, kv: KVCacheManager) -> None:
        self.cfg = cfg
        self.kv = kv
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []          # admission (priority) order
        self.lanes: List[Optional[Request]] = [None] * cfg.n_lanes
        self.total_preemptions = 0
        self.total_admitted = 0

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _budget(self) -> int:
        return self.cfg.token_budget or self.cfg.n_lanes

    # ------------------------------------------------------------------
    def _admit(self, budget_left: int, decision: StepDecision,
               scheduled: List[Request]) -> int:
        while (self.waiting and budget_left > 0
               and None in self.lanes
               and self.kv.can_allocate(len(self.waiting[0].prompt)
                                        + len(self.waiting[0].generated))):
            req = self.waiting.popleft()
            lane = self.lanes.index(None)
            req.begin_run(lane)
            self.lanes[lane] = req
            self.running.append(req)
            self.kv.allocate(req.request_id, 0)
            scheduled.append(req)
            decision.n_admitted += 1
            self.total_admitted += 1
            budget_left -= 1
        return budget_left

    def _preempt(self, victim: Request, decision: StepDecision,
                 scheduled: List[Request]) -> None:
        self.kv.free(victim.request_id)
        self.lanes[victim.lane] = None
        victim.lane = None
        victim.state = RequestState.WAITING
        victim.n_preemptions += 1
        self.running.remove(victim)
        if victim in scheduled:
            scheduled.remove(victim)
        self.waiting.appendleft(victim)        # resume as soon as possible
        decision.n_preempted += 1
        self.total_preemptions += 1

    def schedule(self) -> StepDecision:
        """Pick this step's lanes, admit, and guarantee their KV blocks."""
        decision = StepDecision(scheduled=[])
        budget = self._budget()

        decode = [r for r in self.running if r.is_decode]
        prefill = [r for r in self.running if not r.is_decode]
        scheduled = decode[:budget]
        budget_left = budget - len(scheduled)
        take = prefill[:budget_left]
        scheduled += take
        budget_left -= len(take)

        budget_left = self._admit(budget_left, decision, scheduled)

        # guarantee a KV slot for every scheduled token, in priority order;
        # evict from the back (latest admitted) when the pool runs dry
        for req in [r for r in self.running if r in scheduled]:
            if req not in scheduled:           # evicted by an earlier lane
                continue
            needs_block = self.kv.n_tokens(req.request_id) \
                % self.kv.block_size == 0
            while needs_block and self.kv.num_free_blocks == 0:
                victim = self.running[-1]
                if victim is req and len(self.running) == 1:
                    raise RuntimeError(
                        "KV pool too small for a single sequence: "
                        f"request {req.request_id} needs a block and no "
                        "victim remains")
                self._preempt(victim, decision, scheduled)
                if victim is req:
                    break
            if req in scheduled:
                self.kv.append_token(req.request_id)

        decision.scheduled = scheduled
        decision.n_decode = sum(1 for r in scheduled if r.is_decode)
        decision.n_prefill = len(scheduled) - decision.n_decode
        return decision

    # ------------------------------------------------------------------
    def finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.done = True
        self.kv.free(req.request_id)
        self.lanes[req.lane] = None
        req.lane = None
        self.running.remove(req)
