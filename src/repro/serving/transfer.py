"""KV-block wire format + the edge<->DC disaggregated serving coordinator.

The paper's thesis is that shipping work to a remote DCAI system beats
computing locally *despite* the data-movement cost (§4.1's linear transfer
model decides when).  Mapped onto the serving stack, the natural split is
**prefill in the data center, decode at the edge**: prefill is the
compute-bound phase a DCAI accelerator crushes, decode is latency-bound and
belongs next to the user.  What crosses the WAN is the prompt's paged KV
state, block by block.

This module provides the three pieces:

  * **Wire format** — :class:`KVShipment`: the full KV blocks covering a
    prompt prefix, each as a :class:`KVBlockRecord` carrying its chain
    digest (:func:`repro.serving.blocks.chain_digest`), parent digest,
    token ids, per-part K/V payload arrays, and a sha256 payload checksum.
    Tokens past the last full block travel as ``partial_tokens`` (token
    history only, no KV — the decode side must re-process at least one
    token anyway to produce logits, so the partial tail is recomputed
    there through the ordinary admission path).  ``serialize()`` produces
    a single self-describing byte string; ``deserialize()`` verifies every
    payload checksum *and* recomputes every chain digest from
    ``(parent, tokens)``, raising :class:`TransferIntegrityError` on any
    corruption.  Because blocks are content-addressed by the same digests
    the prefix cache uses, the cache doubles as the transfer dedup layer:
    ``drop_payloads()`` strips the payloads of blocks the receiver already
    holds, so shared prompt prefixes cross the WAN once.  The same bytes
    are the prefix-cache persistence format
    (:meth:`PagedDecodeEngine.save_prefix_cache`).

  * **Topology** — :func:`edge_dc_topology`: a two-facility ``"dc"`` <->
    ``"edge"`` topology for the KV link, with the paper's DTN NIC and RTT
    constants but a streaming-friendly per-file startup (a persistent KV
    session does not pay a Globus task submission per block batch).

  * **Coordinator** — :class:`DisaggregatedEngine`: routes each request
    prefill -> transfer -> decode across two :class:`PagedDecodeEngine`
    instances, charging DC prefill as *modeled* time (measured wall /
    ``dc_speedup``), the KV shipment through the
    :class:`~repro.core.transfer.TransferService` cost model
    (concurrency-dependent rate, startup, control RTT), and edge decode as
    *measured* time on one shared :class:`~repro.core.simclock.SimClock`.
    ``priced_turnaround()`` re-prices the recorded shipments at any link
    bandwidth and ``crossover_bandwidth()`` bisects for the bandwidth at
    which the split starts beating one-engine serving.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import struct
import time
from typing import Any, Dict, FrozenSet, List, Optional, Set, Union

import numpy as np

from repro.core.facility import Facility, Topology, WanLink
from repro.core.simclock import SimClock
from repro.core.transfer import DataStore, FileRef, TransferService
from repro.serving.blocks import chain_digest

# part -> {"k": ndarray, "v": ndarray}, each (n_layers, block_size, Hkv, D);
# int8 pools add "k_scale"/"v_scale" scale planes (n_layers, block_size, Hkv)
ArrayPayload = Dict[str, Dict[str, np.ndarray]]

_MAGIC = b"KVSHIP01"


class TransferIntegrityError(RuntimeError):
    """A shipment failed verification: corrupt payload bytes, a token
    history that no longer hashes to its advertised chain digest, or a
    dedup-stripped block the receiver does not actually hold."""


def payload_checksum(payload: ArrayPayload) -> str:
    """Sha256 over a block payload's canonical byte representation.

    Canonical order is sorted part names, then sorted array names within a
    part (``k``/``v``, plus ``k_scale``/``v_scale`` for int8 pools), with
    each array's dtype and shape mixed into the hash before its raw
    bytes — so a payload that was reshaped, retyped, or bit-flipped in
    flight fails verification even at identical byte length.
    """
    h = hashlib.sha256()
    for part in sorted(payload):
        for name in sorted(payload[part]):
            arr = np.ascontiguousarray(payload[part][name])
            h.update(f"{part}/{name}:{arr.dtype}:{arr.shape}".encode())
            h.update(arr.tobytes())
    return h.hexdigest()


def _payload_nbytes(payload: Optional[ArrayPayload]) -> int:
    """Raw KV bytes in one block payload (0 for a stripped payload)."""
    if payload is None:
        return 0
    return sum(arr.nbytes for part in payload.values()
               for arr in part.values())


@dataclasses.dataclass
class KVBlockRecord:
    """One full KV block on the wire.

    ``digest`` / ``parent`` are chain digests (content addresses — see
    :func:`repro.serving.blocks.chain_digest`), ``tokens`` the block's
    token ids, ``payload`` the per-part K/V arrays read off the sender's
    device pools (``None`` after a dedup strip), and ``checksum`` the
    sender-side :func:`payload_checksum` — kept even when the payload is
    stripped, so the record still certifies what the receiver's cached
    copy must contain.
    """

    digest: str
    parent: str
    tokens: List[int]
    payload: Optional[ArrayPayload]
    checksum: str


@dataclasses.dataclass
class KVShipment:
    """A prompt prefix's KV state, packaged for the WAN (or for disk).

    ``blocks`` are the full blocks in chain order (parents before
    children); ``partial_tokens`` the token-history tail past the last
    full block — shipped without KV, recomputed on the decode side.
    One serialized shipment is one stored object but logically
    ``1 + n_payloads`` wire files (manifest + per-block payloads); the
    transfer cost model prices it that way via its ``n_files`` override.
    """

    block_size: int
    blocks: List[KVBlockRecord]
    partial_tokens: List[int]

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        """Full blocks described by the shipment (with or without KV)."""
        return len(self.blocks)

    @property
    def n_payloads(self) -> int:
        """Blocks still carrying their KV payload (not dedup-stripped)."""
        return sum(1 for b in self.blocks if b.payload is not None)

    @property
    def payload_nbytes(self) -> int:
        """Raw KV bytes across all carried payloads."""
        return sum(_payload_nbytes(b.payload) for b in self.blocks)

    @property
    def tokens_covered(self) -> int:
        """Prompt tokens whose KV the full blocks cover."""
        return self.n_blocks * self.block_size

    # ------------------------------------------------------------------
    def drop_payloads(self, present: Union[Set[str], FrozenSet[str]]
                      ) -> "KVShipment":
        """Dedup against the receiver: strip payloads of blocks whose
        digest the receiver already caches.

        The records themselves stay (digest + tokens + checksum), so the
        receiver can verify the chain and assert it really holds every
        stripped block.  Returns a new shipment; payload arrays are shared,
        not copied.
        """
        blocks = [b if b.digest not in present else
                  dataclasses.replace(b, payload=None)
                  for b in self.blocks]
        return KVShipment(self.block_size, blocks, list(self.partial_tokens))

    # ------------------------------------------------------------------
    def serialize(self) -> bytes:
        """Pack the shipment into one self-describing byte string.

        Layout: ``KVSHIP01`` magic, little-endian uint32 header length, a
        JSON header (digests, tokens, checksums, array dtypes/shapes),
        then the raw array buffers concatenated in header order.  The
        header is canonical (sorted keys), so identical shipments
        serialize to identical bytes on any host.
        """
        buffers: List[bytes] = []
        blocks_hdr = []
        for rec in self.blocks:
            arrays = None
            if rec.payload is not None:
                arrays = []
                for part in sorted(rec.payload):
                    for name in sorted(rec.payload[part]):
                        arr = np.ascontiguousarray(rec.payload[part][name])
                        arrays.append({"part": part, "name": name,
                                       "dtype": str(arr.dtype),
                                       "shape": list(arr.shape),
                                       "nbytes": arr.nbytes})
                        buffers.append(arr.tobytes())
            blocks_hdr.append({"digest": rec.digest, "parent": rec.parent,
                               "tokens": rec.tokens,
                               "checksum": rec.checksum, "arrays": arrays})
        header = {"block_size": self.block_size,
                  "partial_tokens": [int(t) for t in self.partial_tokens],
                  "blocks": blocks_hdr}
        hjson = json.dumps(header, sort_keys=True,
                           separators=(",", ":")).encode()
        return b"".join([_MAGIC, struct.pack("<I", len(hjson)), hjson,
                         *buffers])

    @classmethod
    def deserialize(cls, data: bytes) -> "KVShipment":
        """Unpack and *verify* a serialized shipment.

        Every carried payload's checksum is recomputed over the decoded
        arrays, and every block's chain digest is recomputed from its
        ``(parent, tokens)`` — a mismatch in either raises
        :class:`TransferIntegrityError`, so a corrupted shipment can never
        be attached to a sequence.
        """
        if data[:len(_MAGIC)] != _MAGIC:
            raise TransferIntegrityError(
                "not a KV shipment (bad magic/version)")
        off = len(_MAGIC)
        (hlen,) = struct.unpack_from("<I", data, off)
        off += 4
        try:
            header = json.loads(data[off:off + hlen].decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise TransferIntegrityError(f"corrupt shipment header: {e}")
        off += hlen
        blocks: List[KVBlockRecord] = []
        for bh in header["blocks"]:
            payload: Optional[ArrayPayload] = None
            if bh["arrays"] is not None:
                payload = {}
                for ah in bh["arrays"]:
                    nbytes = ah["nbytes"]
                    if off + nbytes > len(data):
                        raise TransferIntegrityError(
                            "truncated shipment: payload bytes missing")
                    arr = np.frombuffer(
                        data[off:off + nbytes],
                        dtype=np.dtype(ah["dtype"])).reshape(ah["shape"])
                    payload.setdefault(ah["part"], {})[ah["name"]] = arr
                    off += nbytes
            rec = KVBlockRecord(digest=bh["digest"], parent=bh["parent"],
                                tokens=[int(t) for t in bh["tokens"]],
                                payload=payload, checksum=bh["checksum"])
            if chain_digest(rec.parent, rec.tokens) != rec.digest:
                raise TransferIntegrityError(
                    f"chain digest mismatch for block {rec.digest[:12]}: "
                    "token history corrupted in flight")
            if payload is not None and payload_checksum(payload) \
                    != rec.checksum:
                raise TransferIntegrityError(
                    f"payload checksum mismatch for block "
                    f"{rec.digest[:12]}: KV bytes corrupted in flight")
            blocks.append(rec)
        return cls(block_size=int(header["block_size"]), blocks=blocks,
                   partial_tokens=[int(t)
                                   for t in header["partial_tokens"]])


# ---------------------------------------------------------------------------
def edge_dc_topology(nic_bps: float = 1.25e9, *, backbone_bps: float = 12.5e9,
                     rtt: float = 0.048,
                     per_file_startup: float = 0.05) -> Topology:
    """Two-facility topology for the KV link: ``"dc"`` <-> ``"edge"``.

    Defaults mirror the paper's deployment constants (10 Gbps DTN NIC =
    1.25 GB/s, 100 Gbps backbone, 48 ms RTT) except ``per_file_startup``:
    a streaming KV handoff rides a persistent session, so ``S`` here is
    per-batch connection setup (~50 ms), not the 0.6 s Globus task
    submission the bulk-file model pays.  Pass ``per_file_startup=0.6`` to
    price shipments as individual Globus tasks instead.
    """
    topo = Topology()
    topo.add_facility(Facility("dc"))
    topo.add_facility(Facility("edge"))
    for src, dst in (("dc", "edge"), ("edge", "dc")):
        topo.add_link(WanLink(src, dst, backbone_bps=backbone_bps,
                              nic_bps=nic_bps, rtt=rtt,
                              per_file_startup=per_file_startup))
    return topo


# ---------------------------------------------------------------------------
class DisaggregatedEngine:
    """Prefill at the DC, decode at the edge, KV blocks over the WAN.

    Wraps two :class:`~repro.serving.engine.PagedDecodeEngine` instances
    (both with the prefix cache enabled, same ``block_size``) behind the
    familiar ``submit`` / ``run_until_drained`` surface.  Per drained
    batch:

      1. **DC prefill** — every pending prompt runs on the prefill engine
         for exactly one new token (continuous-batched together).  Wall
         time is measured, then *charged* to the clock as
         ``wall / dc_speedup`` — the DCAI accelerator is modeled, the
         math is real.  The emitted first token rides along as a handoff
         cross-check.
      2. **Transfer** — each prompt's full KV blocks are exported
         (:meth:`PagedDecodeEngine.export_kv_prefix`), dedup-stripped
         against the decode engine's cached digests, serialized, and
         submitted to the :class:`~repro.core.transfer.TransferService`,
         which prices them with the paper's ``T = x/v + S`` model (one
         shipment = manifest + per-block payload files for the
         concurrency curve) and advances the shared clock.
      3. **Edge decode** — the decode engine imports the shipment
         (verify -> register -> device-pool write), then serves the
         request normally: ``begin_seq`` attaches the imported chain as a
         prefix hit, the partial tail recomputes, and decode proceeds
         with tiling and speculation unchanged.  Wall time is measured
         into the clock.  Greedy decoding makes the handoff exactly
         token-identical to single-engine serving — asserted against the
         DC-emitted first token when ``check_handoff`` is on.

    Dedup accounting (``bytes_naive`` vs ``bytes_shipped``) quantifies
    what content-addressing saves on prefix-heavy fleets; the recorded
    shipments let :meth:`priced_turnaround` re-price the run at any link
    bandwidth and :meth:`crossover_bandwidth` locate where the split
    beats one-engine serving.
    """

    def __init__(self, prefill_engine, decode_engine, *,
                 transfer: Optional[TransferService] = None,
                 clock: Optional[SimClock] = None,
                 dc: str = "dc", edge: str = "edge",
                 nic_bps: float = 1.25e9, dc_speedup: float = 8.0,
                 concurrency: int = 8,
                 check_handoff: bool = True) -> None:
        """Wire the coordinator to its two engines and the cost model.

        With no ``transfer`` service given, a private one is built over
        :func:`edge_dc_topology` at ``nic_bps`` (fault-free, deterministic).
        ``dc_speedup`` is the modeled DCAI-vs-edge compute ratio applied to
        the measured prefill wall; ``concurrency`` the WAN stream count.
        """
        if prefill_engine.block_size != decode_engine.block_size:
            raise ValueError(
                "prefill and decode engines must share block_size "
                f"({prefill_engine.block_size} != "
                f"{decode_engine.block_size}): chain digests are computed "
                "over block-sized token runs")
        for name, eng in (("prefill", prefill_engine),
                          ("decode", decode_engine)):
            if not eng.kv.enable_prefix_cache:
                raise ValueError(
                    f"{name} engine needs prefix_cache=True: the prefix "
                    "cache is both the export source and the import target")
        self.prefill = prefill_engine
        self.decode = decode_engine
        self.dc = dc
        self.edge = edge
        self.dc_speedup = float(dc_speedup)
        self.concurrency = int(concurrency)
        self.check_handoff = check_handoff
        if transfer is None:
            clock = clock or SimClock()
            transfer = TransferService(edge_dc_topology(nic_bps), clock,
                                       DataStore(),
                                       default_concurrency=concurrency)
        self.transfer = transfer
        self.clock = transfer.clock
        # both sides stamp t_submit / t_first_token / t_done from the
        # shared virtual clock, so disaggregated TTFT rows are comparable
        # with wall-clock engines (same stamping code, different clock)
        self.prefill.set_clock(self.clock)
        self.decode.set_clock(self.clock)
        self._pending: List[tuple] = []
        self._next_id = 0
        self._shipment_counter = 0
        # accounting the bench and the crossover analysis read
        self.prefill_wall = 0.0
        self.decode_wall = 0.0
        self.transfer_seconds = 0.0
        self.bytes_naive = 0
        self.bytes_shipped = 0
        self.blocks_exported = 0
        self.blocks_dedup_skipped = 0
        self.blocks_imported = 0
        self.partial_tokens_reshipped = 0
        self.handoff_checks = 0
        # (wire bytes, logical file count) per shipment, for re-pricing
        self.shipments: List[tuple] = []

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Queue a request for the next drain; returns its request id."""
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, np.asarray(prompt, np.int32),
                              int(max_new_tokens)))
        return rid

    # ------------------------------------------------------------------
    def _ship_one(self, prompt: np.ndarray) -> Dict[str, int]:
        """Export -> dedup -> transfer -> import one prompt's KV prefix.

        Returns the decode-side import stats for the shipment.  Dedup is
        content-addressed: blocks another request in this very batch
        already shipped are stripped too, so a shared preamble crosses the
        WAN exactly once.
        """
        shipment = self.prefill.export_kv_prefix(prompt)
        self.blocks_exported += shipment.n_blocks
        self.partial_tokens_reshipped += len(shipment.partial_tokens)
        naive = len(shipment.serialize())
        deduped = shipment.drop_payloads(self.decode.cached_digests())
        wire = deduped.serialize()
        self.bytes_naive += naive
        self.bytes_shipped += len(wire)
        self.blocks_dedup_skipped += deduped.n_blocks - deduped.n_payloads

        self._shipment_counter += 1
        name = f"kvship-{self._shipment_counter:05d}"
        self.transfer.store.put(self.dc, FileRef(name, len(wire),
                                                 payload=wire))
        n_files = 1 + deduped.n_payloads        # manifest + block payloads
        self.transfer.submit(self.dc, self.edge, [name],
                             concurrency=self.concurrency, n_files=n_files,
                             label=f"{name} kv {self.dc}->{self.edge}")
        self.shipments.append((len(wire), n_files))

        received = KVShipment.deserialize(
            self.transfer.store.get(self.edge, name).payload)
        stats = self.decode.import_kv_shipment(received)
        self.blocks_imported += stats["imported"]
        return stats

    def run_until_drained(self) -> List[Any]:
        """Serve every queued request through prefill->transfer->decode.

        Returns the finished :class:`~repro.serving.scheduler.Request`
        objects (re-keyed to this coordinator's request ids, in id order)
        — the same objects single-engine ``run_until_drained`` would hand
        back, token-identical under greedy decoding.
        """
        out: List[Any] = []
        while self._pending:
            batch, self._pending = self._pending, []

            # 1. DC prefill: one continuous batch, one emitted token each
            pre_ids = {}
            for rid, prompt, _ in batch:
                pre_ids[self.prefill.submit(prompt, 1)] = rid
            t0 = time.perf_counter()
            pre_done = self.prefill.run_until_drained()
            wall = time.perf_counter() - t0
            self.prefill_wall += wall
            self.clock.charge(wall / self.dc_speedup,
                              f"dc prefill x{len(batch)} (modeled DCAI)")
            first_tok = {pre_ids[r.request_id]: r.generated[:1]
                         for r in pre_done}

            # 2+3. ship KV, then decode at the edge
            dec_ids = {}
            for rid, prompt, max_new in batch:
                self._ship_one(prompt)
                dec_ids[self.decode.submit(prompt, max_new)] = rid
            with self.clock.measure(f"edge decode x{len(batch)}"):
                t0 = time.perf_counter()
                dec_done = self.decode.run_until_drained()
                self.decode_wall += time.perf_counter() - t0
            for r in dec_done:
                rid = dec_ids[r.request_id]
                expect = first_tok.get(rid)
                if self.check_handoff and expect:
                    self.handoff_checks += 1
                    if r.generated[:1] != expect:
                        raise RuntimeError(
                            f"disaggregated handoff diverged on request "
                            f"{rid}: DC prefill emitted {expect[0]}, edge "
                            f"decode emitted {r.generated[0]} — the "
                            "shipped KV does not reproduce the prompt "
                            "state")
                r.request_id = rid
                out.append(r)
        self.transfer_seconds = sum(r.duration
                                    for r in self.transfer.records)
        return sorted(out, key=lambda r: r.request_id)

    # ------------------------------------------------------------------
    def priced_turnaround(self, nic_bps: Optional[float] = None, *,
                          dc_speedup: Optional[float] = None,
                          per_file_startup: Optional[float] = None
                          ) -> Dict[str, float]:
        """Re-price the recorded run at a different link bandwidth.

        Uses the measured prefill/decode walls and the recorded shipment
        sizes, recomputing only the transfer term with the §4.1 model at
        ``nic_bps`` — so one served fleet yields the whole
        turnaround-vs-bandwidth curve without re-running the model.
        Returns ``{"prefill", "transfer", "decode", "total"}`` seconds.
        """
        speedup = self.dc_speedup if dc_speedup is None else dc_speedup
        if nic_bps is None:
            xfer = sum(r.duration for r in self.transfer.records)
        else:
            kw = {} if per_file_startup is None \
                else {"per_file_startup": per_file_startup}
            link = edge_dc_topology(nic_bps, **kw).link("dc", "edge")
            xfer = 0.0
            for nbytes, n_files in self.shipments:
                conc = min(self.concurrency, n_files)
                v = link.effective_rate(conc)
                startup = link.per_file_startup * \
                    ((n_files + conc - 1) // conc)
                xfer += nbytes / v + startup + 2 * link.rtt
        prefill = self.prefill_wall / max(speedup, 1e-9)
        return {"prefill": prefill, "transfer": xfer,
                "decode": self.decode_wall,
                "total": prefill + xfer + self.decode_wall}

    def crossover_bandwidth(self, baseline_seconds: float, *,
                            lo: float = 1e4, hi: float = 1e13,
                            iters: int = 60) -> Optional[float]:
        """Smallest link bandwidth (bytes/s) at which the disaggregated
        turnaround beats ``baseline_seconds`` (one-engine serving).

        Bisects the monotone transfer term of :meth:`priced_turnaround`.
        Returns ``None`` when even an infinite link loses (the fixed
        startup + control cost exceeds the DC compute win — one-engine
        serving always wins at this scale) and ``lo`` when even the
        slowest probed link wins.
        """
        if self.priced_turnaround(hi)["total"] > baseline_seconds:
            return None
        if self.priced_turnaround(lo)["total"] <= baseline_seconds:
            return lo
        a, b = lo, hi
        for _ in range(iters):
            mid = (a * b) ** 0.5          # geometric: bandwidth spans decades
            if self.priced_turnaround(mid)["total"] <= baseline_seconds:
                b = mid
            else:
                a = mid
        return b

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Coordinator accounting: walls, clock breakdown, dedup bytes."""
        bd = self.clock.breakdown()
        return {
            "requests": self._next_id,
            "prefill_wall": self.prefill_wall,
            "decode_wall": self.decode_wall,
            "transfer_seconds": self.transfer_seconds,
            "turnaround": bd["total"],
            "modeled_seconds": bd["modeled"],
            "sim_seconds": bd["sim"],
            "real_seconds": bd["real"],
            "bytes_naive": self.bytes_naive,
            "bytes_shipped": self.bytes_shipped,
            "dedup_savings": 1.0 - self.bytes_shipped
            / max(self.bytes_naive, 1),
            "blocks_exported": self.blocks_exported,
            "blocks_dedup_skipped": self.blocks_dedup_skipped,
            "blocks_imported": self.blocks_imported,
            "handoff_checks": self.handoff_checks,
        }
