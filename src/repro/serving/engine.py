"""Autoregressive LM serving engines — the paper's "E"(stimate) hot loop.

Two interchangeable decode engines behind one facade:

  * :class:`PagedDecodeEngine` — continuous batching over a **paged KV
    cache**: requests borrow fixed-size blocks from a shared pool
    (serving/blocks.py) under a unified token-budget scheduler
    (serving/scheduler.py).  Every engine step is one token-budgeted batch
    mixing multi-token prefill chunks and single-token decodes through one
    compiled ``paged_step`` path; identical prompt prefixes are shared
    copy-on-write through the manager's prefix cache instead of being
    re-prefilled.  Memory is committed per block actually used, so at equal
    memory budget it admits far more concurrent requests than dense
    per-slot slabs.
  * :class:`SlotDecodeEngine` — the dense reference: one ``cache_len`` slab
    per lane, kept for model families whose decode state is O(1) recurrent
    (ssm/hybrid/audio) and as the equivalence oracle for the paged path.

``DecodeEngine(api, params, ...)`` picks the paged engine whenever the
model family supports it (transformer-backed: dense / moe / vlm) and the
dense-slot engine otherwise — the public surface (``submit`` /
``step`` / ``run_until_drained``) is identical.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.serving.batch import RaggedBatch, padded_pow2
from repro.serving.blocks import KVCacheManager
from repro.serving.scheduler import (Request, RequestState, Scheduler,
                                     SchedulerConfig, StepDecision)
from repro.serving.spec import NgramProposer, Proposer

PyTree = Any


def _emit_token(engine, req: "Request", tok: int) -> bool:
    """THE shared step-completion emission — every engine class routes
    token emission through here, so ``t_first_token`` / ``t_done`` are
    stamped exactly once and from the engine's clock abstraction
    (``engine._now()``: wall time, or a shared SimClock in disaggregated /
    open-loop runs — which is what makes TTFT rows comparable across
    engine kinds).  Appends the token, bumps the decode counter, stamps
    the latency marks, and fires the engine's ``on_token`` streaming
    callback.  Returns True when the request just finished (hit its
    ``max_new_tokens`` or the EOS token)."""
    req.generated.append(tok)
    req.feed.append(tok)
    engine.tokens_decoded += 1
    if req.t_first_token == 0.0:
        req.t_first_token = engine._now()
    finished = (len(req.generated) >= req.max_new_tokens
                or tok == engine.eos)
    if finished:
        req.t_done = engine._now()
    if engine.on_token is not None:
        engine.on_token(req.request_id, tok, finished)
    return finished


def _mesh_dp_tp(mesh):
    """(data-parallel degree, tensor-parallel degree) of a serving mesh:
    tp is the "model" axis, dp the product of everything else."""
    from repro.launch.mesh import mesh_axis_sizes
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    dp = 1
    for name, n in sizes.items():
        if name != "model":
            dp *= n
    return dp, tp


def DecodeEngine(model_api, params: PyTree, *, paged: Optional[bool] = None,
                 mesh=None, **kw):
    """Facade: the paged engine when the model family supports it, the
    dense-slot engine otherwise.  ``paged=True/False`` forces the choice.

    ``mesh`` (a ``jax.sharding.Mesh``) serves across every device it
    holds: the "model" axis shards one engine tensor-parallel, while any
    data axis > 1 routes to :class:`ShardedDecodeEngine` — one full paged
    engine per data slice.  Mesh serving requires the paged path.
    """
    if paged is None:
        paged = getattr(model_api, "supports_paged", False)
    if mesh is not None:
        if not paged:
            raise ValueError(
                f"{model_api.cfg.family} models have no paged-KV decode "
                "path; mesh serving shards the paged engine only")
        dp, _ = _mesh_dp_tp(mesh)
        if dp > 1:
            return ShardedDecodeEngine(model_api, params, mesh=mesh, **kw)
        return PagedDecodeEngine(model_api, params, mesh=mesh, **kw)
    cls = PagedDecodeEngine if paged else SlotDecodeEngine
    return cls(model_api, params, **kw)


# ---------------------------------------------------------------------------
class PagedDecodeEngine:
    """Continuous-batching decode over a block-paged KV pool.

    ``n_slots`` is the number of concurrent lanes the jitted step batches
    over; ``cache_len`` caps one request's logical KV length.  The physical
    pool defaults to the dense-equivalent size (``n_slots`` full sequences,
    plus the null block) — pass a smaller ``num_blocks`` to oversubscribe
    memory and exercise preemption, or a larger one to admit more lanes
    than dense slabs could.

    Batch layout (``ragged``, default True for families providing
    ``ragged_step``): every step's scheduled tokens are flattened into one
    1-D stream with per-token (lane, position, KV-slot) metadata — a mixed
    prefill+decode step costs ~``sum(q_len)`` tokens of model work.
    ``ragged=False`` pins the legacy rectangular ``(n_slots, chunk_width)``
    layout, where one lane prefilling a wide chunk pads every decoding
    lane to the same width (``lanes * max(q_len)`` work) — kept as the PR 2
    baseline and for the padding-tax comparison in bench_serving.

    Attention grid (``tiled``, default True under ``ragged``): the flat
    stream is segment-tiled (``tile`` q rows per window, split at segment
    boundaries — serving/batch.py::TileMap), so the paged-attention read
    sweeps each lane's KV blocks once per q-tile instead of once per
    token.  ``tiled=False`` pins the per-token ``(token, head, block)``
    grid as the measured baseline.

    Speculative decode (``spec``, default True wherever the multi-token
    step exists): each decode lane schedules up to ``draft_k`` proposer
    drafts as one ``1 + k``-token segment per step; the step's per-row
    greedy argmax verifies them, the longest matching draft prefix plus
    one bonus token is accepted (always >= 1 token — zero acceptance
    degrades exactly to the plain decode step), and the KV cache is
    rewound past the rejected slots (``KVCacheManager.rewind``).  Greedy
    outputs are token-identical to ``spec=False`` (which pins the
    one-token-per-step decode) for ANY proposer; the default
    :class:`~repro.serving.spec.NgramProposer` drafts from each request's
    own token history, so acceptance is free on the repetitive tails long
    generations settle into.  This is the one path where a request
    advances a *variable* number of tokens per engine iteration —
    positions, slot mapping, budget accounting, and preemption all ride
    the same multi-token segment bookkeeping chunked prefill uses.
    """

    def __init__(self, model_api, params: PyTree, *, n_slots: int,
                 cache_len: int, eos_token: int = -1, window: int = 0,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 token_budget: int = 0, chunk_tokens: int = 16,
                 prefix_cache: bool = True, ragged: Optional[bool] = None,
                 tiled: Optional[bool] = None, tile: int = 16,
                 spec: bool = True, draft_k: int = 4,
                 proposer: Optional[Proposer] = None,
                 host_swap: bool = True,
                 host_swap_blocks: Optional[int] = None,
                 ttft_target: float = 0.0, tpot_target: float = 0.0,
                 clock=None,
                 mesh=None, cache_dtype=None, compute_dtype=None) -> None:
        """Build the paged engine: block pool, scheduler, jitted steps.

        ``ragged``/``tiled`` default to on where supported; ``spec=True``
        wires the speculative path with an :class:`NgramProposer` unless
        ``proposer`` overrides it.  ``num_blocks`` defaults to the pool
        that matches ``n_slots * cache_len`` tokens.

        ``host_swap`` (on wherever the prefix cache is) backs the device
        pool with a host-side block tier: a registered block evicted from
        the device — a preempted sequence's prefix, or a cold cached
        chain — parks its payload in host memory instead of being lost,
        and a later admission swaps it back into a fresh device block
        rather than recomputing it.  ``host_swap_blocks`` caps the tier
        (LRU-dropped beyond it; default unbounded).

        ``ttft_target`` / ``tpot_target`` (seconds, 0 = off) arm the
        scheduler's SLO-aware admission: chunk-shrink and admission
        shedding when observed decode TPOT slips past target (see
        :class:`~repro.serving.scheduler.SchedulerConfig`).  ``clock``
        (a :class:`~repro.core.simclock.SimClock`) replaces wall time for
        every latency stamp — the disaggregated engine installs its
        shared clock on both sides so TTFT rows stay comparable.

        ``cache_dtype=jnp.int8`` stores the paged KV pools quantized
        (per-(block, slot, kv-head) symmetric scales ride in parallel
        ``k_scale``/``v_scale`` pools) — half/quarter the pool bytes, with
        dequantization fused into the attention read.

        ``mesh`` (a ``jax.sharding.Mesh`` whose data axes are size 1)
        runs this one engine tensor-parallel over the mesh's "model"
        axis: parameters take the serving rule table
        (:func:`repro.launch.sharding.serving_param_specs`), the KV pools
        shard their kv-head dim (:func:`paged_pool_specs` — replicating
        when GQA heads don't divide), and every host-built metadata array
        is committed replicated, so the compiled step partitions by GSPMD
        propagation alone.  Scheduler, block pool, CoW, speculation, and
        transfer logic are untouched — they address logical block ids,
        which are identical on every shard.
        """
        if not getattr(model_api, "supports_paged", False):
            raise ValueError(
                f"{model_api.cfg.family} models have no paged-KV decode "
                "path; use DecodeEngine (it falls back to dense slots)")
        self.api = model_api
        self.params = params
        self.mesh = mesh
        self.tp = 1
        self._repl = None               # replicated sharding for metadata
        self._pool_shardings = None     # canonical NamedShardings per pool
        if mesh is not None:
            dp, self.tp = _mesh_dp_tp(mesh)
            if dp > 1:
                raise ValueError(
                    f"mesh has a data-parallel extent of {dp}; "
                    "PagedDecodeEngine shards ONE engine tensor-parallel — "
                    "use ShardedDecodeEngine (or DecodeEngine(mesh=...)) "
                    "for data-parallel slices")
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.eos = eos_token
        self.window = window
        self.block_size = block_size
        if chunk_tokens < 1:
            # unlike the raw SchedulerConfig, the engine compiles one step
            # per pow2 chunk width, so an "unlimited" chunk is not meaningful
            raise ValueError("chunk_tokens must be >= 1 "
                             "(1 = one-token-per-step prefill)")
        if getattr(model_api, "paged_step", None) is None:
            chunk_tokens = 1          # legacy q_len=1 step: no chunking
            spec = False              # q_len=1: no multi-token verification
        # ragged flat-token batching is the default whenever the model
        # family provides the flat step; ``ragged=False`` pins the legacy
        # rectangular (n_slots, chunk_width) layout (the PR 2 baseline)
        ragged_fn = getattr(model_api, "ragged_step", None)
        if ragged is None:
            ragged = ragged_fn is not None
        if ragged and ragged_fn is None:
            raise ValueError(
                f"{model_api.cfg.family} models have no ragged_step; "
                "pass ragged=False for the rectangular paged path")
        self.ragged = ragged
        # the segment-tiled attention grid is the ragged default; tiled=False
        # pins the per-token (token, head, block) grid as the baseline
        if tiled is None:
            tiled = ragged
        if tiled and not ragged:
            raise ValueError("tiled=True requires the ragged flat-token "
                             "layout (pass ragged=True)")
        if tile < 1 or tile & (tile - 1):
            raise ValueError(f"tile must be a positive power of two, "
                             f"got {tile}")
        self.tiled = tiled
        self.tile = tile
        self.chunk_tokens = chunk_tokens
        if draft_k < 0:
            raise ValueError(f"draft_k must be >= 0, got {draft_k}")
        self.spec = bool(spec) and draft_k > 0
        self.draft_k = draft_k if self.spec else 0
        if self.spec and proposer is None:
            proposer = NgramProposer()
        self.proposer = proposer if self.spec else None
        self.max_blocks = -(-cache_len // block_size)
        if num_blocks is None:
            num_blocks = n_slots * self.max_blocks + 1   # +1: null block
        self.num_blocks = num_blocks
        self.kv = KVCacheManager(num_blocks, block_size,
                                 max_blocks_per_seq=self.max_blocks,
                                 enable_prefix_cache=prefix_cache)
        # device->host swap tier: digest -> {"parent", "tokens", "payload"},
        # LRU-ordered.  Installed as the manager's host_has/on_swap_out
        # hooks so eviction parks payloads here and admission plans
        # swap-ins against it.
        self.host_swap = bool(host_swap) and prefix_cache
        self.host_swap_blocks = host_swap_blocks
        self._host_tier: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        # digests mid-import whose device payload write has not landed yet:
        # the swap-out hook must not capture their (garbage) device bytes
        self._swap_quarantine: set = set()
        self.host_swap_outs = 0
        self.host_swap_ins = 0
        self.host_swap_drops = 0
        if self.host_swap:
            self.kv.host_has = self._host_tier.__contains__
            self.kv.on_swap_out = self._swap_out_block
        self.scheduler = Scheduler(
            SchedulerConfig(n_lanes=n_slots, token_budget=token_budget,
                            chunk_tokens=self.chunk_tokens,
                            fill_to_bucket=self.ragged,
                            draft_k=self.draft_k, proposer=self.proposer,
                            ttft_target=ttft_target,
                            tpot_target=tpot_target),
            self.kv)
        # clock abstraction: latency stamps (t_submit / t_first_token /
        # t_done, and the scheduler's SLO deadlines) read self._now() —
        # wall time by default, a shared SimClock when one is installed
        self.clock = None
        self.set_clock(clock)
        # per-token streaming hook: on_token(request_id, token, finished),
        # fired from the step thread by the shared emission helper
        self.on_token = None
        kw = {"num_blocks": num_blocks, "block_size": block_size,
              "max_blocks_per_lane": self.max_blocks}
        if cache_dtype is not None:
            kw["dtype"] = cache_dtype
        self.cache = model_api.init_paged_cache(n_slots, **kw)
        if self.ragged:
            # ragged_step tracks per-token positions, not per-lane "pos";
            # drop it now so the first step's cache signature matches every
            # later one (a lingering key = one pointless retrace per bucket)
            self.cache.pop("pos", None)
        self.kv_heads_sharded = False
        if mesh is not None:
            from repro.launch import sharding as shlib
            from repro.launch.mesh import mesh_axis_sizes
            axes = mesh_axis_sizes(mesh)
            pspecs = shlib.serving_param_specs(params, axes)
            self.params = jax.device_put(params,
                                         shlib.to_named(pspecs, mesh))
            cspecs = shlib.paged_pool_specs(self.cache, axes)
            self._pool_shardings = shlib.to_named(cspecs, mesh)
            self.cache = jax.device_put(self.cache, self._pool_shardings)
            self._repl = NamedSharding(mesh, P())
            self.kv_heads_sharded = any(
                "model" in s for s in jax.tree.leaves(
                    cspecs, is_leaf=lambda x: isinstance(x, P)))
        step_kw = {"window": window}
        if self.ragged and self.tiled:
            step_kw["tile"] = tile     # static TileMap q-window rows
        if compute_dtype is not None:
            step_kw["compute_dtype"] = compute_dtype
        if mesh is not None and mesh.devices.size > 1:
            # shard-local dispatch: the partitioned step must lower to the
            # GSPMD-partitionable jnp reference attention on every shard —
            # the Pallas kernel is a single-device lowering (its scalar
            # prefetch and pool indexing assume the whole pool is local)
            step_kw["use_kernel"] = False
        # donate the cache: the KV pool is updated in place rather than
        # double-buffered (decisive for pool size = device memory on TPU).
        # Rectangular: one jitted step per pow2 chunk width (O(log
        # chunk_tokens) retraces, decode-only steps stay at width 1).
        # Ragged: one jitted step per pow2 *total token count* (O(log
        # token_budget) retraces) — the flat stream has no per-lane width
        # at all, so a mixed prefill+decode step does work proportional to
        # the real scheduled tokens.
        if self.ragged:
            step_fn = ragged_fn
        else:
            step_fn = model_api.resolve_paged_step() \
                if hasattr(model_api, "resolve_paged_step") \
                else (getattr(model_api, "paged_step", None)
                      or model_api.paged_decode_step)
        self._step = jax.jit(
            lambda p, c, t: step_fn(p, c, t, **step_kw),
            donate_argnums=(1,))
        self._cow = jax.jit(self._apply_copies, donate_argnums=(0,))
        self._finished: List[Request] = []
        self._next_id = 0
        self.tokens_decoded = 0
        self.tokens_prefilled = 0
        # cancellation / SLO-shed accounting
        self.cancelled = 0
        self.shed = 0
        self.host_purged = 0            # host-tier entries cancel reclaimed
        self.cow_block_copies = 0
        self.steps = 0
        # padding-tax accounting: real scheduled tokens vs flat/rect slots
        # the compiled step actually processed
        self.scheduled_tokens = 0
        self.padded_tokens = 0
        # speculative-decode accounting: drafted vs accepted draft tokens,
        # and per-verification emitted counts (always >= 1: the bonus)
        self.tokens_drafted = 0
        self.draft_tokens_accepted = 0
        self.spec_verifications = 0       # decode emissions that had drafts
        self.spec_tokens_emitted = 0      # tokens those emissions produced
        # mesh accounting: collectives in ONE compiled step (counted from
        # the first bucket's optimized HLO, lazily) and their running total
        self._collectives_per_step: Optional[int] = None
        self.collective_ops = 0

    # ------------------------------------------------------------------
    def _put(self, x):
        """Commit a host-built array to the device — replicated across the
        mesh in mesh mode, so GSPMD partitions the step from the sharded
        params/pools alone (the replicated-metadata contract: block
        tables, per-token lane/pos/slot metadata, and tile maps are
        identical bytes on every shard)."""
        x = jnp.asarray(x)
        if self._repl is None:
            return x
        return jax.device_put(x, self._repl)

    def _count_collectives(self, tokens) -> int:
        """Collectives per compiled step, from the optimized HLO of the
        current bucket (counted once; -1 when the backend can't report)."""
        try:
            txt = self._step.lower(self.params, self.cache,
                                   tokens).compile().as_text()
        except Exception:
            return -1
        import re
        return len(re.findall(
            r"\b(?:all-reduce|all-gather|reduce-scatter"
            r"|collective-permute|all-to-all)(?:-start)?\(", txt))

    # ------------------------------------------------------------------
    def set_clock(self, clock) -> None:
        """Install a :class:`~repro.core.simclock.SimClock` as the source
        of every latency stamp (``None`` restores wall time).  The
        scheduler's SLO deadlines follow the same clock, so virtual-time
        open-loop runs and wall-clock serving share one admission
        policy."""
        self.clock = clock
        self.scheduler.now_fn = self._now

    def _now(self) -> float:
        """Current time on the engine's clock: the installed SimClock's
        sim time, else the process wall clock."""
        return self.clock.now if self.clock is not None \
            else time.perf_counter()

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0) -> int:
        """Queue a request; returns its id.  Rejects requests whose total
        length (prompt + new tokens) can never fit the pool.  ``priority``
        is the scheduler's admission/preemption class (higher admits
        first, evicted last; default 0 keeps plain FIFO)."""
        prompt = np.asarray(prompt, np.int32)
        total = len(prompt) + max_new_tokens
        usable = min(self.max_blocks, self.num_blocks - 1)
        if self.kv.blocks_needed(total) > usable:
            raise ValueError(
                f"request of {total} tokens needs "
                f"{self.kv.blocks_needed(total)} blocks; engine can serve "
                f"at most {usable} per request")
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, prompt, max_new_tokens, priority=priority)
        req.t_submit = self._now()
        self.scheduler.add(req)
        return rid

    def cancel(self, request_id: int) -> bool:
        """Abort a queued or mid-flight request between steps, freeing
        everything it holds: its lane, its KV blocks, its prefix-cache
        registrations no other live sequence shares
        (:meth:`~repro.serving.blocks.KVCacheManager.release_seq`), any
        queued host->device swap-ins, and the host-tier payloads of its
        now-unregistered chain — so a cancel-everything drain returns the
        pool AND the host tier to empty.  The cancelled request lands in
        the finished list with ``cancelled=True`` and whatever tokens it
        had emitted.  Returns False when the id is unknown or already
        finished (cancelling a completed request is a harmless no-op).

        Only legal between steps — the async frontend serializes cancels
        with ``step()`` on its step thread, which is what makes
        mid-*stream* disconnects safe."""
        req = next((r for r in self.scheduler.running
                    if r.request_id == request_id), None)
        if req is None:
            req = next((r for r in self.scheduler.waiting
                        if r.request_id == request_id), None)
        if req is None:
            return False
        # the feed whose chain residue must be reclaimed: the live feed
        # for a running sequence, prompt + generated for a waiting one
        # (a preempted victim's KV may live on only in the host tier)
        feed = req.feed if req.state is RequestState.RUNNING and req.feed \
            else [int(t) for t in req.prompt] + list(req.generated)
        self.scheduler.abort(req)
        purge: List[str] = []
        if self.kv.has_seq(request_id):
            purge += self.kv.release_seq(request_id)
        purge += self.kv.release_chain(feed)
        for d in purge:
            if self._host_tier.pop(d, None) is not None:
                self.host_purged += 1
        req.t_done = self._now()
        self._finished.append(req)
        self.cancelled += 1
        return True

    # ------------------------------------------------------------------
    @staticmethod
    def _apply_copies(cache: Dict, src: jax.Array, dst: jax.Array) -> Dict:
        """Copy-on-write block copies: pool[dst] = pool[src] for every
        pool leaf — K and V, plus the scale planes of quantized pools
        (every leaf carries the block axis at dim 1; padding pairs are
        (0, 0) — a null-block self-copy no-op)."""
        out = dict(cache)
        for part in ("scan", "head"):
            if part in cache:
                out[part] = {name: arr.at[:, dst].set(arr[:, src])
                             for name, arr in cache[part].items()}
        return out

    def _run_rect(self, decision: StepDecision):
        """The rectangular (n_slots, chunk_width) step: every lane is
        padded to the widest scheduled chunk.  Returns ``greedy(req, j)``,
        the step's argmax token at row ``j`` of ``req``'s chunk."""
        sched_ids = {r.request_id for r in decision.scheduled}
        width = padded_pow2(max(
            [decision.num_scheduled[r.request_id]
             for r in decision.scheduled] or [1]))
        tokens = np.zeros((self.n_slots, width), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        q_lens = np.zeros((self.n_slots,), np.int32)
        tables = np.zeros((self.n_slots, self.max_blocks), np.int32)
        # paused (budget-deferred) lanes keep q_lens = 0: their writes are
        # routed to the null block and their logits ignored — harmless
        for r in self.scheduler.running:
            pos[r.lane] = r.cursor
            tables[r.lane] = self.kv.padded_table(r.request_id)
            if r.request_id in sched_ids:
                n = decision.num_scheduled[r.request_id]
                q_lens[r.lane] = n
                tokens[r.lane, :n] = decision.segment_tokens(r)
        self.cache["block_tables"] = self._put(tables)
        self.cache["pos"] = self._put(pos)
        self.cache["q_lens"] = self._put(q_lens)
        dev_tokens = self._put(tokens)
        if self.mesh is not None and self._collectives_per_step is None:
            self._collectives_per_step = self._count_collectives(dev_tokens)
        logits, self.cache = self._step(self.params, self.cache, dev_tokens)
        self.collective_ops += max(self._collectives_per_step or 0, 0)
        self.scheduled_tokens += int(q_lens.sum())
        self.padded_tokens += self.n_slots * width
        if decision.drafts:
            # speculative verification reads every row of a draft segment
            # — but still only those: gather them (plus each lane's last
            # row) before the argmax instead of reducing all (slots, C)
            flat = logits.reshape(self.n_slots * width, -1)
            return self._gather_greedy(
                decision, flat, lambda r: r.lane * width)
        # only each lane's last real chunk row can emit — gather those
        # (n_slots, V) rows before the argmax instead of reducing all C
        last = jnp.asarray(np.maximum(q_lens - 1, 0))
        lane_tok = np.asarray(jnp.argmax(
            logits[jnp.arange(self.n_slots), last], axis=-1))   # (slots,)
        return lambda r, j: int(lane_tok[r.lane])

    def _gather_greedy(self, decision: StepDecision, flat_logits,
                       seg_start):
        """Argmax only the rows verification can read: for each scheduled
        request, rows ``base-1 .. n-1`` of its segment (the draft
        verification window — just the emitting row when it has no
        drafts).  ``seg_start(req)`` maps a request to its segment's
        first flat row.  Returns ``greedy(req, j)`` over those rows."""
        offsets: Dict[int, int] = {}
        rows: List[int] = []
        for r in decision.scheduled:
            n = decision.num_scheduled[r.request_id]
            first = n - 1 - len(decision.drafts.get(r.request_id, ()))
            offsets[r.request_id] = len(rows) - first
            start = seg_start(r)
            rows.extend(range(start + first, start + n))
        toks = np.asarray(jnp.argmax(
            flat_logits[jnp.asarray(np.asarray(rows, np.int32))], axis=-1))
        return lambda r, j: int(toks[offsets[r.request_id] + j])

    def _run_ragged(self, decision: StepDecision):
        """The flat-token step: all scheduled tokens as one 1-D stream with
        per-token lane/pos/slot metadata — work proportional to the real
        token count, ~sum(q_len) instead of lanes * max(q_len).  Returns
        ``greedy(req, j)``, the step's argmax token at row ``j`` of
        ``req``'s segment."""
        batch = RaggedBatch.build(decision, self.kv, self.n_slots,
                                  self.block_size,
                                  cap=self.scheduler._budget())
        tables = np.zeros((self.n_slots, self.max_blocks), np.int32)
        for r in self.scheduler.running:
            tables[r.lane] = self.kv.padded_table(r.request_id)
        self.cache["block_tables"] = self._put(tables)
        self.cache["token_lane"] = self._put(batch.token_lane)
        self.cache["token_pos"] = self._put(batch.token_pos)
        self.cache["slot_mapping"] = self._put(batch.slot_mapping)
        if self.tiled:
            # segment-tile the stream: tile capacity is a pure function of
            # the pow2 bucket (windows + n_slots), so the jitted step still
            # retraces per bucket only
            tiles = batch.tiles(self.n_slots, self.tile)
            self.cache["tile_meta"] = self._put(tiles.meta)
            self.cache["row_tile"] = self._put(tiles.row_tile)
        dev_tokens = self._put(batch.tokens)
        if self.mesh is not None and self._collectives_per_step is None:
            self._collectives_per_step = self._count_collectives(dev_tokens)
        logits, self.cache = self._step(self.params, self.cache, dev_tokens)
        self.collective_ops += max(self._collectives_per_step or 0, 0)
        self.scheduled_tokens += batch.total_tokens
        self.padded_tokens += batch.padded_tokens
        if decision.drafts:
            # speculative verification reads every row of a draft segment
            # — but still only those: gather them (plus each lane's last
            # row) before the argmax instead of reducing all T
            starts = batch.q_starts
            return self._gather_greedy(decision, logits,
                                       lambda r: starts[r.request_id])
        # only each lane's final segment row can emit — gather those
        # (n_slots, V) rows before the argmax instead of reducing all T
        lane_tok = np.asarray(jnp.argmax(
            logits[jnp.asarray(batch.last_row)], axis=-1))      # (slots,)
        return lambda r, j: int(lane_tok[r.lane])

    def step(self) -> StepDecision:
        """One engine iteration: one token-budgeted batch mixing prefill
        chunks, decodes, and (``spec``) speculative draft segments.

        Propose -> verify -> accept: the scheduler attached each decode
        lane's drafts (``decision.drafts``); the model step verified them
        by producing per-row greedy argmax; here the longest matching
        draft prefix plus one bonus token is accepted per lane, and the
        KV cache is rewound past the rejected draft slots so the next
        step's appends land where the accepted sequence actually ends."""
        t0 = time.perf_counter()
        emitted = 0
        decision = self.scheduler.schedule()
        # host->device swap-ins FIRST: a swapped-in block must hold its
        # payload before a CoW copy reads it (a fully-matched prompt can
        # fork a block this very admission just swapped in) and before
        # the step attends over it
        if self.host_swap:
            swapins = self.kv.take_swap_ins()
            if swapins:
                self._apply_swap_ins(swapins)
            if self.host_swap_blocks is not None:
                # trim AFTER the swap-ins land: a queued swap-in's payload
                # must never be dropped between planning and application
                while len(self._host_tier) > self.host_swap_blocks:
                    self._host_tier.popitem(last=False)
                    self.host_swap_drops += 1
        # apply queued copy-on-write copies BEFORE this step's KV writes
        # land in the forked blocks
        copies = self.kv.take_copy_ops()
        if copies:
            n = padded_pow2(len(copies))
            src = np.zeros((n,), np.int32)
            dst = np.zeros((n,), np.int32)
            for i, (s, d) in enumerate(copies):
                src[i], dst[i] = s, d
            self.cache = self._cow(self.cache, self._put(src),
                                   self._put(dst))
            self.cow_block_copies += len(copies)

        greedy = (self._run_ragged(decision) if self.ragged
                  else self._run_rect(decision))
        self.steps += 1

        for r in list(decision.scheduled):
            n = decision.num_scheduled[r.request_id]
            drafts = decision.drafts.get(r.request_id, [])
            base = n - len(drafts)              # fed (non-draft) tokens
            emitting = r.cursor + base == len(r.feed)
            if not emitting:
                r.cursor += n                   # mid-prompt prefill chunk
                self.tokens_prefilled += n
                continue
            self.tokens_prefilled += base - 1
            # greedy rows base-1 .. n-1 predict the tokens at positions
            # cursor+base .. cursor+n: accept the longest draft prefix the
            # argmax reproduces, plus the bonus token at the first
            # mismatching (or final) row — with no drafts this is exactly
            # the old single-token emission
            m = 0
            while m < len(drafts) and greedy(r, base - 1 + m) == drafts[m]:
                m += 1
            new_toks = [int(t) for t in drafts[:m]] + [greedy(r, base - 1 + m)]
            if drafts:
                self.tokens_drafted += len(drafts)
                self.draft_tokens_accepted += m
                self.spec_verifications += 1
            kept = 0
            finished = False
            for tok in new_toks:
                kept += 1
                finished = _emit_token(self, r, tok)
                if finished:
                    break
            emitted += kept
            if drafts:
                self.spec_tokens_emitted += kept
            # cursor counts feed tokens resident in KV: the fed base plus
            # the accepted drafts that stayed (the bonus token is never in
            # KV — it is fed next step like any fresh decode token)
            r.cursor += base + min(kept, m)
            if finished:
                self.scheduler.finish(r)
                self._finished.append(r)
            elif len(drafts) > m:
                # roll back the rejected draft slots (and free any block
                # that only held rejected tokens) so the KV watermark
                # matches the accepted sequence exactly
                self.kv.rewind(r.request_id, r.cursor)
        shed = self.scheduler.take_shed()
        if shed:
            self._finished.extend(shed)
            self.shed += len(shed)
        # feed the SLO admission loop: real wall seconds per decode token
        # (consistent with SimClock.measure, which also charges real time)
        self.scheduler.observe_step(time.perf_counter() - t0, emitted)
        return decision

    def has_work(self) -> bool:
        """True while requests are queued or running (uniform across the
        engine classes, incl. the sharded front)."""
        return self.scheduler.has_work()

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        """Step until no work remains; returns (and hands off) the requests
        finished since the last call."""
        for _ in range(max_steps):
            if not self.scheduler.has_work():
                break
            decision = self.step()
            if not decision.scheduled and self.scheduler.waiting:
                raise RuntimeError(
                    "serving stalled: waiting requests cannot be admitted "
                    f"({self.kv.num_free_blocks} free blocks)")
        return self.take_finished()

    def take_finished(self) -> List[Request]:
        """Hand off (and clear) the requests finished since the last call —
        the non-blocking collection path the async frontend polls."""
        out, self._finished = self._finished, []
        return out

    # ------------------------------------------------------------------
    # KV transfer / persistence (see repro.serving.transfer)
    # ------------------------------------------------------------------
    def cached_digests(self) -> frozenset:
        """Chain digests of every full block the prefix cache holds — the
        receiver-side set a sender dedups shipments against."""
        return self.kv.cached_digests()

    def _read_block_payload(self, blk: int) -> Dict:
        """Read one physical block's slice of every device pool leaf, as
        host arrays keyed ``part -> {"k", "v", ...}`` (the wire payload
        layout; int8 pools add their ``k_scale``/``v_scale`` planes)."""
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for part in ("scan", "head"):
            if part in self.cache:
                out[part] = {name: np.asarray(arr[:, blk])
                             for name, arr in self.cache[part].items()}
        return out

    def _write_block_payloads(self, blocks: List[int],
                              payloads: List[Dict]) -> None:
        """Scatter host block payloads into the device pools at ``blocks``
        (block axis 1 of every pool leaf), restoring the canonical pool
        shardings afterwards in mesh mode."""
        idx = self._put(np.asarray(blocks, np.int32))
        for part in ("scan", "head"):
            if part not in self.cache:
                continue
            pools = self.cache[part]
            for p in payloads:
                if part not in p or set(p[part]) != set(pools):
                    raise ValueError(
                        f"payload pool-name mismatch on '{part}': got "
                        f"{sorted(p.get(part, {}))}, engine pools are "
                        f"{sorted(pools)} (fp and int8 pools do not mix)")
            new = {}
            for name, arr in pools.items():
                want = arr.shape[:1] + arr.shape[2:]
                for p in payloads:
                    if p[part][name].shape != want:
                        raise ValueError(
                            f"payload KV geometry mismatch on "
                            f"'{part}/{name}': got {p[part][name].shape}, "
                            f"engine pool expects {want}")
                # stack along the block axis: (layers, n_new, ...)
                stack = self._put(np.stack([p[part][name]
                                            for p in payloads], axis=1))
                new[name] = arr.at[:, idx].set(stack.astype(arr.dtype))
            self.cache[part] = new
        if self._pool_shardings is not None:
            # the eager scatter above mixes replicated payloads into
            # head-sharded pools; re-commit the canonical sharding so
            # the per-shard pool invariant survives the write
            for part in ("scan", "head"):
                if part in self.cache:
                    self.cache[part] = jax.device_put(
                        self.cache[part], self._pool_shardings[part])

    # ------------------------------------------------------------------
    # device->host swap tier (tiered KV; see docs/ARCHITECTURE.md)
    # ------------------------------------------------------------------
    def _swap_out_block(self, digest: str, blk: int, parent: str,
                        tokens) -> None:
        """Eviction hook (``KVCacheManager.on_swap_out``): park an evicted
        registered block's device payload in the host tier.

        Skips digests the tier already holds — a swapped-in block being
        re-evicted before its device write landed would capture garbage,
        and the host copy is bit-identical anyway (full blocks are
        immutable once registered) — and quarantined digests mid-import,
        whose payload write is still pending."""
        if digest in self._host_tier:
            self._host_tier.move_to_end(digest)
            return
        if digest in self._swap_quarantine:
            return
        self._host_tier[digest] = {"parent": parent,
                                   "tokens": tuple(int(t) for t in tokens),
                                   "payload": self._read_block_payload(blk)}
        self.host_swap_outs += 1

    def _apply_swap_ins(self, ops: List[Tuple[str, int]]) -> None:
        """Write queued host->device swap-ins into the KV pools.  Runs
        before CoW copies and before the step's own writes; an op whose
        target block was evicted (or re-registered to a different block)
        between planning and application is dropped — the current
        registration, if any, carries its own op."""
        blocks: List[int] = []
        payloads: List[Dict] = []
        for digest, blk in ops:
            if self.kv.digest_block(digest) != blk:
                continue
            ent = self._host_tier.get(digest)
            if ent is None:
                # the planner only swaps in digests host_has() confirmed,
                # and the tier is never trimmed with an op in flight — a
                # miss here would leave a garbage block attached to a
                # live sequence, so fail loudly rather than serve it
                raise RuntimeError(
                    f"swap-in payload for block digest {digest[:12]} "
                    "missing from the host tier")
            self._host_tier.move_to_end(digest)
            blocks.append(blk)
            payloads.append(ent["payload"])
        if not blocks:
            return
        self._write_block_payloads(blocks, payloads)
        self.host_swap_ins += len(blocks)

    def export_kv_prefix(self, feed: np.ndarray):
        """Package the cached KV prefix of ``feed`` as a
        :class:`~repro.serving.transfer.KVShipment`.

        Exports the longest chain of cached full blocks covering the
        feed's prefix — each with its device KV payload and checksum —
        plus the remaining tokens as the payload-free partial tail.  The
        usual source is a just-prefilled prompt (every full block was
        registered as prefill completed it, and registrations survive the
        sequence's ``free`` via the cache's own hold), but any feed whose
        prefix is cached exports the same way.
        """
        from repro.serving.transfer import (KVBlockRecord, KVShipment,
                                            payload_checksum)
        chain = self.kv.export_chain(feed)
        blocks = []
        for digest, parent, blk, tokens in chain:
            payload = self._read_block_payload(blk)
            blocks.append(KVBlockRecord(
                digest=digest, parent=parent, tokens=tokens,
                payload=payload, checksum=payload_checksum(payload)))
        covered = len(chain) * self.block_size
        return KVShipment(block_size=self.block_size, blocks=blocks,
                          partial_tokens=[int(t) for t in feed[covered:]])

    def import_kv_shipment(self, shipment) -> Dict[str, int]:
        """Attach a (verified) shipment's blocks to this engine's cache.

        Each block is registered with the prefix cache under its chain
        digest and its payload written into the device KV pools, so the
        next ``submit`` of the matching prompt attaches the chain as an
        ordinary prefix hit.  Blocks already cached are skipped (the dedup
        contract: a stripped payload must be one of these — anything else
        raises :class:`~repro.serving.transfer.TransferIntegrityError`).
        Imported blocks are immediately evictable, so a shipment can
        never starve live sequences; when the pool genuinely has no room
        the remainder of the chain is dropped (counted, not fatal — the
        decode side just recomputes more).  Returns
        ``{"imported", "dedup_skipped", "dropped_no_space",
        "tokens_attachable"}``.
        """
        from repro.serving.transfer import TransferIntegrityError
        if shipment.block_size != self.block_size:
            raise ValueError(
                f"shipment block_size {shipment.block_size} != engine "
                f"block_size {self.block_size}")
        imported: List[Tuple[str, int]] = []
        payloads: List[Dict] = []
        skipped = dropped = 0
        try:
            for rec in shipment.blocks:
                if self.kv.has_digest(rec.digest):
                    skipped += 1
                    continue
                if rec.payload is None:
                    raise TransferIntegrityError(
                        f"block {rec.digest[:12]} arrived without a payload "
                        "but is not in this engine's cache — dedup stripped "
                        "a block the receiver does not hold")
                # quarantine until the payload write lands: a later
                # import_block can LRU-evict this block, and the swap-out
                # hook must not capture its still-unwritten device bytes
                self._swap_quarantine.add(rec.digest)
                try:
                    blk = self.kv.import_block(rec.parent, rec.tokens,
                                               digest=rec.digest)
                except RuntimeError:
                    # pool full of live sequences: drop the chain's tail
                    dropped = sum(1 for b in shipment.blocks
                                  if not self.kv.has_digest(b.digest))
                    break
                if blk is not None:
                    imported.append((rec.digest, blk))
                    payloads.append(rec.payload)
            # importing can itself evict an earlier import of this very
            # shipment (and recycle its block): write only payloads whose
            # registration survived, into their still-registered blocks
            live = [(b, p) for (d, b), p in zip(imported, payloads)
                    if self.kv.digest_block(d) == b]
            if live:
                self._write_block_payloads([b for b, _ in live],
                                           [p for _, p in live])
        finally:
            for rec in shipment.blocks:
                self._swap_quarantine.discard(rec.digest)
        return {"imported": len(imported), "dedup_skipped": skipped,
                "dropped_no_space": dropped,
                "tokens_attachable": (len(imported) + skipped)
                * self.block_size}

    def save_prefix_cache(self, path: str) -> int:
        """Persist every cached full block to ``path`` and return the
        bytes written.  The on-disk format IS the wire format
        (:class:`~repro.serving.transfer.KVShipment`), so a restarted
        engine reloads with :meth:`load_prefix_cache` and warm prompts hit
        the cache exactly as before the restart."""
        from repro.serving.transfer import (KVBlockRecord, KVShipment,
                                            payload_checksum)
        blocks = []
        for digest, parent, blk, tokens in self.kv.export_all_cached():
            payload = self._read_block_payload(blk)
            blocks.append(KVBlockRecord(
                digest=digest, parent=parent, tokens=tokens,
                payload=payload, checksum=payload_checksum(payload)))
        data = KVShipment(block_size=self.block_size, blocks=blocks,
                          partial_tokens=[]).serialize()
        with open(path, "wb") as f:
            f.write(data)
        return len(data)

    def load_prefix_cache(self, path: str) -> Dict[str, int]:
        """Restore a :meth:`save_prefix_cache` snapshot (verifying every
        checksum and chain digest) into this engine's prefix cache.
        Returns the :meth:`import_kv_shipment` stats."""
        from repro.serving.transfer import KVShipment
        with open(path, "rb") as f:
            data = f.read()
        return self.import_kv_shipment(KVShipment.deserialize(data))

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Counters for benchmarks: token throughput, padding efficiency,
        prefix-cache and speculative-decode accounting."""
        return {
            "steps": self.steps,
            "tokens_decoded": self.tokens_decoded,
            "tokens_prefilled": self.tokens_prefilled,
            "active": len(self.scheduler.running),
            "waiting": len(self.scheduler.waiting),
            "preemptions": self.scheduler.total_preemptions,
            "block_utilization": self.kv.utilization(),
            "prefix_hits": self.kv.prefix_hits,
            "prefix_tokens_reused": self.kv.prefix_tokens_reused,
            "cow_copies": self.kv.cow_copies,
            "cache_evictions": self.kv.evictions,
            "ragged": int(self.ragged),
            "tiled": int(self.tiled),
            "padding_efficiency": (self.scheduled_tokens
                                   / max(self.padded_tokens, 1)),
            "spec": int(self.spec),
            "kv_rewinds": self.kv.rewinds,
            "kv_tokens_rewound": self.kv.tokens_rewound,
            "tokens_drafted": self.tokens_drafted,
            "draft_tokens_accepted": self.draft_tokens_accepted,
            "spec_verifications": self.spec_verifications,
            # accepted drafts + bonus per verification; 1.0 = speculation
            # never pays off, k+1 = every draft lands
            "accepted_per_spec_step": (self.spec_tokens_emitted
                                       / max(self.spec_verifications, 1)),
            "draft_acceptance_rate": (self.draft_tokens_accepted
                                      / max(self.tokens_drafted, 1)),
            # host swap tier (zeros when host_swap=False)
            "host_swap": int(self.host_swap),
            "swap_outs": self.host_swap_outs,
            "swap_ins": self.host_swap_ins,
            "swapped_in_tokens": self.kv.swapped_in_tokens,
            "host_tier_blocks": len(self._host_tier),
            "host_swap_drops": self.host_swap_drops,
            "preempt_swap_outs": self.scheduler.total_swap_outs,
            # cancellation / SLO admission accounting
            "cancelled": self.cancelled,
            "shed": self.shed,
            "released_seqs": self.kv.released_seqs,
            "swap_ins_dropped": self.kv.swap_ins_dropped,
            "host_purged": self.host_purged,
            # mesh / tensor-parallel accounting (tp=1, zeros off-mesh)
            "tp": self.tp,
            "kv_heads_sharded": int(self.kv_heads_sharded),
            "collectives_per_step": max(self._collectives_per_step or 0, 0),
            "collective_ops": self.collective_ops,
        }


# ---------------------------------------------------------------------------
class ShardedDecodeEngine:
    """Data-parallel serving front: one full paged engine per mesh slice.

    The mesh's data axes are cut into ``dp`` slices of ``tp`` devices
    (:func:`repro.launch.mesh.mesh_slices`); each slice runs a complete
    :class:`PagedDecodeEngine` — scheduler, block pool, prefix cache,
    CoW, speculation, transfer — tensor-parallel over its own "model"
    axis.  Requests are routed to the least-loaded slice by outstanding
    tokens (lowest index breaks ties), so open-loop arrivals never queue
    on one slice while another idles; the global output remains a
    deterministic function of the submission sequence (greedy decode per
    request is schedule-independent — the same property the
    single-device differential harness relies on).  Slices share no
    device state; with more than one slice their steps are dispatched
    from a thread pool, overlapping per-slice XLA executions.

    ``n_slots`` (and the pool size derived from it) is PER SLICE — the
    front scales capacity with the mesh rather than splitting a fixed
    budget.
    """

    def __init__(self, model_api, params: PyTree, *, mesh=None,
                 **engine_kw) -> None:
        """Split ``mesh`` (default: all devices, pure data-parallel) into
        slices and build one :class:`PagedDecodeEngine` per slice;
        ``engine_kw`` is forwarded to every slice unchanged."""
        from repro.launch.mesh import make_host_mesh, mesh_slices
        if mesh is None:
            mesh = make_host_mesh()
        self.mesh = mesh
        slices = mesh_slices(mesh)
        self.engines = [PagedDecodeEngine(model_api, params, mesh=m,
                                          **engine_kw)
                        for m in slices]
        self.api = model_api
        self.n_slices = len(self.engines)
        # global request id -> (slice index, slice-local id); slice-local
        # finished requests are handed back under their global id
        self._route: Dict[int, tuple] = {}
        self._gid_of: Dict[tuple, int] = {}
        self._next_id = 0
        self._finished: List[Request] = []
        self._on_token = None
        self.clock = None
        self._pool = (ThreadPoolExecutor(max_workers=self.n_slices)
                      if self.n_slices > 1 else None)

    # ------------------------------------------------------------------
    @staticmethod
    def _outstanding(eng: PagedDecodeEngine) -> int:
        """Tokens a slice still owes: remaining feed plus unemitted budget
        of its running requests, and the full prompt + budget of queued
        ones — the backlog measure least-loaded routing balances."""
        sched = eng.scheduler
        load = 0
        for r in sched.running:
            load += (r.remaining_feed
                     + (r.max_new_tokens - len(r.generated)))
        for r in sched.waiting:
            load += (len(r.prompt) + len(r.generated)
                     + (r.max_new_tokens - len(r.generated)))
        return load

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0) -> int:
        """Queue a request on the least-loaded slice (by outstanding
        tokens; lowest slice index breaks ties, so a fresh fleet fills in
        slice order); returns its global id."""
        gid = self._next_id
        i = min(range(self.n_slices),
                key=lambda k: (self._outstanding(self.engines[k]), k))
        local = self.engines[i].submit(prompt, max_new_tokens,
                                       priority=priority)
        self._next_id += 1
        self._route[gid] = (i, local)
        self._gid_of[(i, local)] = gid
        return gid

    def cancel(self, request_id: int) -> bool:
        """Abort a queued or mid-flight request by global id, delegating
        to its slice (which frees blocks, host-tier entries, and pending
        swap-ins); returns False if unknown or already finished."""
        loc = self._route.get(request_id)
        if loc is None:
            return False
        i, local = loc
        ok = self.engines[i].cancel(local)
        if ok:
            self._collect()
        return ok

    def set_clock(self, clock) -> None:
        """Install one virtual clock on every slice so latency stamps are
        comparable fleet-wide (and against disaggregated rows)."""
        self.clock = clock
        for e in self.engines:
            e.set_clock(clock)

    @property
    def on_token(self):
        """Streaming callback ``(global_id, token, finished)``; setting it
        installs per-slice wrappers that rewrite local ids to global."""
        return self._on_token

    @on_token.setter
    def on_token(self, cb) -> None:
        """Install (or clear, with None) the fleet-wide streaming hook."""
        self._on_token = cb
        for i, e in enumerate(self.engines):
            if cb is None:
                e.on_token = None
            else:
                e.on_token = (lambda rid, tok, fin, _i=i:
                              cb(self._gid_of[(_i, rid)], tok, fin))

    def _collect(self) -> None:
        """Move every slice's finished requests into the global list,
        rewriting their ids back to the global namespace."""
        for i, eng in enumerate(self.engines):
            done, eng._finished = eng._finished, []
            for r in done:
                r.request_id = self._gid_of[(i, r.request_id)]
                self._finished.append(r)

    def has_work(self) -> bool:
        """True while any slice still holds queued or running requests."""
        return any(e.scheduler.has_work() for e in self.engines)

    def step(self) -> None:
        """One iteration of every slice that has work — concurrently when
        there is more than one (each slice's XLA execution releases the
        GIL, so slices genuinely overlap on CPU and on real meshes)."""
        active = [e for e in self.engines if e.scheduler.has_work()]
        if self._pool is not None and len(active) > 1:
            list(self._pool.map(lambda e: e.step(), active))
        else:
            for e in active:
                e.step()
        self._collect()

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        """Step all slices until no work remains; returns (and hands off)
        the requests finished since the last call, under global ids."""
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        return self.take_finished()

    def take_finished(self) -> List[Request]:
        """Hand off (and clear) finished requests under global ids."""
        self._collect()
        out, self._finished = self._finished, []
        return out

    # aggregate counters, so callers written against one engine (the
    # launcher's summary line, bench helpers) read the fleet totals
    @property
    def steps(self) -> int:
        """Max per-slice step count (slices step concurrently)."""
        return max((e.steps for e in self.engines), default=0)

    @property
    def tokens_decoded(self) -> int:
        """Total decoded tokens across all slices."""
        return sum(e.tokens_decoded for e in self.engines)

    @property
    def tokens_prefilled(self) -> int:
        """Total prefilled tokens across all slices."""
        return sum(e.tokens_prefilled for e in self.engines)

    # ------------------------------------------------------------------
    # KV transfer / persistence across the slice set
    # ------------------------------------------------------------------
    def cached_digests(self) -> frozenset:
        """Digests EVERY slice holds — the safe dedup set: a sender may
        strip exactly the blocks no possible receiving slice would miss."""
        out = None
        for e in self.engines:
            d = e.cached_digests()
            out = d if out is None else (out & d)
        return out if out is not None else frozenset()

    def export_kv_prefix(self, feed: np.ndarray):
        """Export ``feed``'s cached prefix from the slice covering the
        most of it (slices cache independently; load-based routing means
        any one slice may hold the longest chain)."""
        best = max(self.engines,
                   key=lambda e: len(e.kv.export_chain(feed)))
        return best.export_kv_prefix(feed)

    def import_kv_shipment(self, shipment) -> Dict[str, int]:
        """Broadcast a shipment into every slice (each has its own pool),
        summing the per-slice stats — so a warmed prefix is a hit no
        matter which slice later serves the matching prompt."""
        total: Dict[str, int] = {}
        for e in self.engines:
            for k, v in e.import_kv_shipment(shipment).items():
                total[k] = total.get(k, 0) + v
        return total

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Aggregated counters plus the per-slice/per-shard breakdown the
        bench and SLO work read imbalance from."""
        per = [e.stats() for e in self.engines]
        agg: Dict[str, Any] = {
            "slices": self.n_slices,
            "tp": per[0]["tp"] if per else 1,
            "steps": max((p["steps"] for p in per), default=0),
            "tokens_decoded": sum(p["tokens_decoded"] for p in per),
            "tokens_prefilled": sum(p["tokens_prefilled"] for p in per),
            "active": sum(p["active"] for p in per),
            "waiting": sum(p["waiting"] for p in per),
            "preemptions": sum(p["preemptions"] for p in per),
            "cancelled": sum(p["cancelled"] for p in per),
            "shed": sum(p["shed"] for p in per),
            "collective_ops": sum(p["collective_ops"] for p in per),
            "collectives_per_step": (per[0]["collectives_per_step"]
                                     if per else 0),
            "padding_efficiency": (
                sum(e.scheduled_tokens for e in self.engines)
                / max(sum(e.padded_tokens for e in self.engines), 1)),
            "tokens_decoded_per_slice": [p["tokens_decoded"] for p in per],
            "tokens_prefilled_per_slice": [p["tokens_prefilled"]
                                           for p in per],
            "collective_ops_per_slice": [p["collective_ops"] for p in per],
            "per_slice": per,
        }
        return agg


# ---------------------------------------------------------------------------
class SlotDecodeEngine:
    """Continuous-batching LM decode over a fixed dense slot grid.

    The cache has ``n_slots`` request slots of ``cache_len`` tokens each;
    every engine step decodes one token for every active slot.  Finished
    slots are freed and refilled from the admission queue; prompts are fed
    token-by-token (prefill-as-decode, correct for every family incl.
    recurrent/SSM models).  For transformer-family KV caches, a slot's
    positions/write-cursor are reset on reuse so a new occupant starts at
    RoPE position 0 and never attends to its predecessor's stale KV.
    """

    def __init__(self, model_api, params: PyTree, *, n_slots: int,
                 cache_len: int, eos_token: int = -1,
                 window: int = 0, cache_dtype=None, compute_dtype=None,
                 **_paged_opts) -> None:
        """Build the dense-slot engine (paged-only options are ignored)."""
        self.api = model_api
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.eos = eos_token
        self.window = window
        kw = {"window": window}
        if cache_dtype is not None:
            kw["dtype"] = cache_dtype
        self.cache = model_api.init_cache(n_slots, cache_len, **kw)
        step_kw = {"window": window}
        if compute_dtype is not None:
            step_kw["compute_dtype"] = compute_dtype
        self._step = jax.jit(
            lambda p, c, t: model_api.decode_step(p, c, t, **step_kw),
            donate_argnums=(1,))
        self.active: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._finished: List[Request] = []
        # rolling KV buffers hold min(window, cache_len) slots per lane
        self._slots_per_lane = min(window, cache_len) if window else cache_len
        self._next_id = 0
        self.tokens_decoded = 0
        self.steps = 0
        self.scheduled_tokens = 0
        self.padded_tokens = 0
        self.cancelled = 0
        self.clock = None
        self.on_token = None

    # ------------------------------------------------------------------
    def set_clock(self, clock) -> None:
        """Install a virtual clock for latency stamps (None = wall clock)."""
        self.clock = clock

    def _now(self) -> float:
        """Current time on the engine's clock abstraction."""
        return self.clock.now if self.clock is not None \
            else time.perf_counter()

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: int = 0) -> int:
        """Queue a request; returns its request id (``priority`` is
        recorded for interface parity — the slot queue stays FIFO)."""
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                      priority=priority)
        req.t_submit = self._now()
        self.queue.append(req)
        return rid

    def cancel(self, request_id: int) -> bool:
        """Abort a queued or active request, freeing its slot; returns
        False if unknown or already finished."""
        for i, req in enumerate(self.queue):
            if req.request_id == request_id:
                del self.queue[i]
                break
        else:
            for slot, req in enumerate(self.active):
                if req is not None and req.request_id == request_id:
                    self.active[slot] = None
                    break
            else:
                return False
        req.done = True
        req.cancelled = True
        req.t_done = self._now()
        self._finished.append(req)
        self.cancelled += 1
        return True

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                req.begin_run(slot)
                self.active[slot] = req
                if "slot_positions" in self.cache and "scan" in self.cache:
                    # transformer-family rolling KV (pure cache, no recurrent
                    # state): invalidate the previous occupant's entries and
                    # restart the write cursor, so the new request starts at
                    # position 0 and never sees stale KV.  Families with
                    # recurrent state (zamba/xlstm/encdec) keep the seed
                    # behaviour — their lane state cannot be row-reset.
                    self.cache["slot_positions"] = \
                        self.cache["slot_positions"].at[slot].set(-1)
                    self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: one token per active slot."""
        self._admit()
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None:
                tokens[slot, 0] = req.feed[req.cursor]
        self.scheduled_tokens += sum(1 for a in self.active if a is not None)
        self.padded_tokens += self.n_slots
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        next_tokens = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.steps += 1

        for slot, req in enumerate(self.active):
            if req is None:
                continue
            emitting = req.cursor >= len(req.feed) - 1
            req.cursor += 1
            if emitting:
                tok = int(next_tokens[slot])
                if _emit_token(self, req, tok):
                    req.done = True
                    self.active[slot] = None
                    self._finished.append(req)

    def has_work(self) -> bool:
        """True while requests are queued or occupy a slot."""
        return bool(self.queue) or any(a is not None for a in self.active)

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        """Step until no work remains; returns (and hands off) the requests
        finished since the last call."""
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return self.take_finished()

    def take_finished(self) -> List[Request]:
        """Hand off (and clear) the requests finished since the last call."""
        out, self._finished = self._finished, []
        return out

    def stats(self) -> Dict[str, float]:
        """Engine counters: steps, tokens, occupancy, padding efficiency."""
        n_active = sum(1 for a in self.active if a is not None)
        used = sum(min(r.cursor, self._slots_per_lane)
                   for r in self.active if r is not None)
        return {
            "steps": self.steps,
            "tokens_decoded": self.tokens_decoded,
            "active": n_active,
            "waiting": len(self.queue),
            "preemptions": 0,
            "block_utilization": used / max(
                self.n_slots * self._slots_per_lane, 1),
            "padding_efficiency": (self.scheduled_tokens
                                   / max(self.padded_tokens, 1)),
        }
